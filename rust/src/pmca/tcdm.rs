//! TCDM (tightly-coupled data memory) footprint model — Fig. 4b.
//!
//! Working set for one layer's LoRA invocation at `t` parallel tokens,
//! FP16 streams, double-buffered where the DMA overlaps compute:
//!
//! * activations X: t×m, double-buffered (in-flight + in-use),
//! * adapter weights A (m×r) and B (r×n): resident, single copy,
//! * tile results XW: t×n, double-buffered,
//! * rank-space intermediate XA: t×r,
//! * fused output: t×n (written in place over XW's in-use buffer).
//!
//! When the footprint exceeds the 128 KiB TCDM the workload needs either
//! a larger TCDM or extra TCDM↔SRAM traffic — exactly the regime the
//! paper flags for the 512×128 layer at large t.

use super::cluster::SnitchCluster;
use super::kernels::{LoraWorkload, FP16_BYTES};

#[derive(Clone, Copy, Debug)]
pub struct TcdmFootprint {
    pub activations: usize,
    pub adapters: usize,
    pub tile_results: usize,
    pub intermediate: usize,
}

impl TcdmFootprint {
    pub fn total(&self) -> usize {
        self.activations + self.adapters + self.tile_results + self.intermediate
    }

    pub fn kib(&self) -> f64 {
        self.total() as f64 / 1024.0
    }
}

pub fn footprint(w: &LoraWorkload) -> TcdmFootprint {
    TcdmFootprint {
        activations: 2 * w.t * w.m * FP16_BYTES,
        adapters: (w.m * w.r + w.r * w.n) * FP16_BYTES,
        tile_results: 2 * w.t * w.n * FP16_BYTES,
        intermediate: w.t * w.r * FP16_BYTES,
    }
}

/// Does the working set fit the cluster's TCDM?
pub fn fits(w: &LoraWorkload, cluster: &SnitchCluster) -> bool {
    footprint(w).total() <= cluster.tcdm_bytes
}

/// Largest power-of-two token batch that fits the TCDM.
pub fn max_tokens(m: usize, n: usize, r: usize, cluster: &SnitchCluster) -> usize {
    let mut best = 0;
    let mut t = 1;
    while t <= 1024 {
        if fits(
            &LoraWorkload { m, n, r, t },
            cluster,
        ) {
            best = t;
        }
        t *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_with_tokens() {
        let f8 = footprint(&LoraWorkload { m: 128, n: 128, r: 8, t: 8 });
        let f128 = footprint(&LoraWorkload { m: 128, n: 128, r: 8, t: 128 });
        assert!(f128.total() > f8.total());
    }

    #[test]
    fn fig4b_small_layer_range() {
        // paper: 128x128 layer needs ~8.2-21 KiB over t = 8..128.
        let lo = footprint(&LoraWorkload { m: 128, n: 128, r: 8, t: 8 }).kib();
        assert!((4.0..32.0).contains(&lo), "lo={lo}");
    }

    #[test]
    fn fig4b_large_layer_exceeds_tcdm_at_high_t() {
        // paper: 512x128 at large t needs more than the 128 KiB TCDM.
        let c = SnitchCluster::default();
        let big = LoraWorkload { m: 512, n: 128, r: 8, t: 128 };
        assert!(!fits(&big, &c), "{:?}", footprint(&big));
        let small = LoraWorkload { m: 512, n: 128, r: 8, t: 8 };
        assert!(fits(&small, &c));
    }

    #[test]
    fn max_tokens_monotone_in_layer_size() {
        let c = SnitchCluster::default();
        assert!(max_tokens(128, 128, 8, &c) >= max_tokens(512, 128, 8, &c));
        assert!(max_tokens(512, 128, 8, &c) >= 8);
    }

    #[test]
    fn adapters_are_token_independent() {
        let a = footprint(&LoraWorkload { m: 256, n: 256, r: 8, t: 8 }).adapters;
        let b = footprint(&LoraWorkload { m: 256, n: 256, r: 8, t: 128 }).adapters;
        assert_eq!(a, b);
    }
}
