//! Cycle models for the PMCA's per-layer LoRA workload.
//!
//! For `t` parallel tokens through a layer with weight matrix `m×n` and
//! LoRA rank `r`, the PMCA must (Fig. 1b):
//!
//! 1. receive the tile outputs `XW` (t×n) from the AIMC periphery (DMA),
//! 2. compute `XA` (t×m·r MACs) and `(XA)B` (t×r·n MACs) on RedMulE,
//! 3. add `XW + XAB` element-wise on the worker cores (t×n),
//! 4. ship the result onward (DMA).
//!
//! The DMA manager core double-buffers transfers behind compute (the
//! Snitch cluster's dedicated DMA core exists exactly for this), so
//! latency is `overhead + max(compute, dma)` per invocation.

use super::cluster::SnitchCluster;
use super::redmule::RedMulE;

pub const FP16_BYTES: usize = 2;

/// One layer's LoRA workload for a token batch.
#[derive(Clone, Copy, Debug)]
pub struct LoraWorkload {
    /// Weight matrix rows (input features).
    pub m: usize,
    /// Weight matrix cols (output features).
    pub n: usize,
    /// LoRA rank.
    pub r: usize,
    /// Parallel tokens processed per AIMC→PMCA hand-off.
    pub t: usize,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct CycleBreakdown {
    pub xa_cycles: u64,
    pub xab_cycles: u64,
    pub add_cycles: u64,
    pub dma_cycles: u64,
    pub overhead_cycles: u64,
}

impl CycleBreakdown {
    /// Compute-path cycles (RedMulE + cores, serialised on the data dep).
    pub fn compute(&self) -> u64 {
        self.xa_cycles + self.xab_cycles + self.add_cycles
    }

    /// Total latency with DMA double-buffered behind compute.
    pub fn total(&self) -> u64 {
        self.overhead_cycles + self.compute().max(self.dma_cycles)
    }
}

impl LoraWorkload {
    pub fn new(m: usize, n: usize, r: usize, t: usize) -> LoraWorkload {
        LoraWorkload { m, n, r, t }
    }

    /// Same layer/rank at a different token parallelism — the shape the
    /// balance sweep and the serving scheduler iterate over.
    pub fn with_tokens(self, t: usize) -> LoraWorkload {
        LoraWorkload { t, ..self }
    }

    pub fn macs(&self) -> u64 {
        (self.t * self.r * (self.m + self.n)) as u64
    }

    /// Bytes the DMA must move for one invocation: activations X in,
    /// tile results XW in, fused outputs back out (FP16 streams).
    pub fn dma_bytes(&self) -> usize {
        FP16_BYTES * (self.t * self.m + 2 * self.t * self.n)
    }

    pub fn cycles(&self, cluster: &SnitchCluster, engine: &RedMulE) -> CycleBreakdown {
        // Both matmuls are *rank-bound* on RedMulE: X·A has only r output
        // columns (array under-filled laterally) and (XA)·B has an
        // accumulation depth of r (pipeline under-filled temporally), so
        // the engine runs at its rank-r occupancy for the whole LoRA op.
        let eff = engine.effective_macs_per_cycle(self.r);
        CycleBreakdown {
            xa_cycles: ((self.t * self.m * self.r) as f64 / eff).ceil() as u64,
            xab_cycles: ((self.t * self.r * self.n) as f64 / eff).ceil() as u64,
            add_cycles: cluster.vector_op_cycles(self.t * self.n),
            dma_cycles: cluster.dma_cycles(self.dma_bytes()),
            overhead_cycles: cluster.launch_overhead_cycles,
        }
    }

    /// End-to-end PMCA latency in nanoseconds.
    pub fn latency_ns(&self, cluster: &SnitchCluster, engine: &RedMulE) -> f64 {
        cluster.cycles_to_ns(self.cycles(cluster, engine).total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_env() -> (SnitchCluster, RedMulE) {
        (SnitchCluster::default(), RedMulE::default())
    }

    #[test]
    fn macs_formula() {
        let w = LoraWorkload {
            m: 128,
            n: 128,
            r: 8,
            t: 128,
        };
        assert_eq!(w.macs(), 128 * 8 * 256);
    }

    #[test]
    fn latency_scales_with_tokens() {
        let (c, e) = default_env();
        let lat = |t| {
            LoraWorkload {
                m: 512,
                n: 128,
                r: 8,
                t,
            }
            .latency_ns(&c, &e)
        };
        assert!(lat(128) > lat(64));
        assert!(lat(64) > lat(8));
    }

    #[test]
    fn latency_scales_with_rank() {
        let (c, e) = default_env();
        let lat = |r| {
            LoraWorkload {
                m: 128,
                n: 128,
                r,
                t: 64,
            }
            .latency_ns(&c, &e)
        };
        // higher rank: more MACs but also better RedMulE occupancy on XAB;
        // the XA matmul (inner=m) dominates, so total must still grow.
        assert!(lat(16) > lat(8));
        assert!(lat(8) > lat(1));
    }

    #[test]
    fn overhead_dominates_tiny_batches() {
        let (c, e) = default_env();
        let w = LoraWorkload {
            m: 16,
            n: 16,
            r: 1,
            t: 1,
        };
        let b = w.cycles(&c, &e);
        assert!(b.overhead_cycles > b.compute());
    }

    #[test]
    fn compute_dominates_big_batches() {
        let (c, e) = default_env();
        let w = LoraWorkload {
            m: 512,
            n: 128,
            r: 8,
            t: 128,
        };
        let b = w.cycles(&c, &e);
        assert!(b.compute() > b.dma_cycles, "{b:?}");
        assert!(b.compute() > 10 * b.overhead_cycles);
    }
}
