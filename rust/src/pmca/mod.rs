//! PMCA — RISC-V Programmable Multi-Core Accelerator performance model.
//!
//! Models the paper's digital processing unit (Methods — PMCA Performance
//! Estimation): a small Snitch cluster — nine in-order RV32IMAF cores
//! (8 workers + 1 DMA manager), FREP + SSR ISA extensions giving ~90 %
//! FPU utilisation on dense loops, a 128 KiB tightly-coupled data memory
//! (TCDM) behind a single-cycle interconnect, and a RedMulE matrix
//! accelerator configured with 32 FMA blocks (FP16).
//!
//! The paper obtained cycle counts from RTL simulation; this offline
//! reproduction uses an analytic cycle model whose free parameters are
//! calibrated so the PMCA/AIMC latency *ratios* of Fig. 4a are
//! reproduced (see `pipeline::balance::tests`); DESIGN.md
//! §Substitutions records the rationale.

pub mod cluster;
pub mod kernels;
pub mod redmule;
pub mod tcdm;
