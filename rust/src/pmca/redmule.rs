//! RedMulE effective-throughput model.
//!
//! RedMulE (Tortorella et al. 2022) is a systolic FP16 matrix engine; at
//! 32 FMA blocks its peak is 32 MACs/cycle. Peak assumes deep inner
//! dimensions that keep the accumulate pipeline full. The LoRA workload
//! is deliberately *skinny* — inner dimension = rank r ≤ 16 — so the
//! engine stalls on pipeline refills between rank-r dot products.
//!
//! We model this with a classic occupancy curve
//!
//! ```text
//! util(r) = r / (r + r_half)
//! ```
//!
//! where `r_half` (the inner dimension at 50 % utilisation) is the one
//! calibrated parameter; `r_half = 6.5` reproduces the PMCA/AIMC latency
//! ratios the paper reports in Fig. 4a across both layer sizes and all
//! three integration times to within ~15 % (see
//! `pipeline::balance::tests::fig4a_ratio_calibration`).

#[derive(Clone, Debug)]
pub struct RedMulE {
    pub fma_blocks: usize,
    /// Inner dimension at which the pipeline reaches 50 % occupancy.
    pub r_half: f64,
}

impl Default for RedMulE {
    fn default() -> Self {
        RedMulE {
            fma_blocks: 32,
            r_half: 6.5,
        }
    }
}

impl RedMulE {
    /// Pipeline occupancy for a matmul whose inner dimension is `inner`.
    pub fn utilization(&self, inner: usize) -> f64 {
        let r = inner as f64;
        r / (r + self.r_half)
    }

    /// Effective MACs/cycle for inner dimension `inner`.
    pub fn effective_macs_per_cycle(&self, inner: usize) -> f64 {
        self.fma_blocks as f64 * self.utilization(inner)
    }

    /// Cycles to compute an (m×k)·(k×n) matmul.
    pub fn matmul_cycles(&self, m: usize, k: usize, n: usize) -> u64 {
        let macs = (m * k * n) as f64;
        (macs / self.effective_macs_per_cycle(k)).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_monotone_in_inner_dim() {
        let r = RedMulE::default();
        assert!(r.utilization(1) < r.utilization(8));
        assert!(r.utilization(8) < r.utilization(256));
        assert!(r.utilization(4096) > 0.99);
    }

    #[test]
    fn calibration_point_rank8() {
        // r=8: util = 8/14.5 ~ 0.552 -> ~17.7 MAC/cycle of 32 peak.
        let r = RedMulE::default();
        let eff = r.effective_macs_per_cycle(8);
        assert!((eff - 17.655).abs() < 0.1, "eff={eff}");
    }

    #[test]
    fn deep_matmul_near_peak() {
        let r = RedMulE::default();
        let cycles = r.matmul_cycles(128, 512, 128);
        let ideal = (128 * 512 * 128) as f64 / 32.0;
        assert!((cycles as f64) < ideal * 1.02);
    }
}
