//! Snitch-cluster configuration and cycle/latency accounting.

/// Cluster architectural parameters (Methods — PMCA Performance
/// Estimation). Defaults model the paper's "small Snitch cluster".
#[derive(Clone, Debug)]
pub struct SnitchCluster {
    /// Worker cores executing parallel FP loops (one more core manages
    /// the DMA engine and is not counted here).
    pub worker_cores: usize,
    /// SIMD lanes per 32-bit FPU in FP16 (mixed-precision SIMD).
    pub simd_lanes: usize,
    /// Sustained FPU utilisation with FREP + SSR (paper: up to ~90 %).
    pub fpu_util: f64,
    /// RedMulE fused-multiply-accumulate blocks (paper config: 32).
    pub redmule_fma: usize,
    /// TCDM capacity in bytes (paper: 128 KiB).
    pub tcdm_bytes: usize,
    /// DMA engine sustained bandwidth, bytes/cycle (64-bit AXI beat).
    pub dma_bytes_per_cycle: f64,
    /// Fixed per-offload overhead: kernel launch, barriers, SSR setup.
    pub launch_overhead_cycles: u64,
    /// Core clock, Hz (for cycle→ns conversion).
    pub freq_hz: f64,
}

impl Default for SnitchCluster {
    fn default() -> Self {
        SnitchCluster {
            worker_cores: 8,
            simd_lanes: 2,
            fpu_util: 0.9,
            redmule_fma: 32,
            tcdm_bytes: 128 * 1024,
            dma_bytes_per_cycle: 8.0,
            launch_overhead_cycles: 300,
            freq_hz: 1.0e9,
        }
    }
}

impl SnitchCluster {
    /// Peak MACs/cycle of the worker cores in FP16 SIMD.
    pub fn core_macs_per_cycle(&self) -> f64 {
        self.worker_cores as f64 * self.simd_lanes as f64 * self.fpu_util
    }

    /// Cycles for an element-wise vector op of `n` elements on the cores.
    pub fn vector_op_cycles(&self, n: usize) -> u64 {
        (n as f64 / self.core_macs_per_cycle()).ceil() as u64
    }

    /// Cycles for a DMA transfer of `bytes`.
    pub fn dma_cycles(&self, bytes: usize) -> u64 {
        (bytes as f64 / self.dma_bytes_per_cycle).ceil() as u64
    }

    /// Wall time for a DMA transfer of `bytes` at the core clock (ns).
    pub fn dma_ns(&self, bytes: usize) -> f64 {
        self.cycles_to_ns(self.dma_cycles(bytes))
    }

    pub fn cycles_to_ns(&self, cycles: u64) -> f64 {
        cycles as f64 * 1e9 / self.freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_text() {
        let c = SnitchCluster::default();
        assert_eq!(c.worker_cores, 8);
        assert_eq!(c.redmule_fma, 32);
        assert_eq!(c.tcdm_bytes, 128 * 1024);
        assert!((c.fpu_util - 0.9).abs() < 1e-12);
    }

    #[test]
    fn vector_throughput() {
        let c = SnitchCluster::default();
        // 14.4 MAC/cycle -> 14400 elements ~ 1000 cycles
        assert_eq!(c.vector_op_cycles(14_400), 1000);
    }

    #[test]
    fn cycle_ns_conversion() {
        let c = SnitchCluster::default();
        assert_eq!(c.cycles_to_ns(1000), 1000.0); // 1 GHz: 1 cycle = 1 ns
    }
}
