//! AIMC tile latency + the two-stage AIMC→PMCA software pipeline.

use crate::pmca::cluster::SnitchCluster;
use crate::pmca::kernels::LoraWorkload;
use crate::pmca::redmule::RedMulE;

/// AIMC tile integration times evaluated in the paper (ns per MVM).
pub const INTEGRATION_TIMES_NS: [f64; 3] = [128.0, 256.0, 512.0];

/// Token parallelism values evaluated in the paper.
pub const TOKEN_PARALLELISM: [usize; 5] = [8, 16, 32, 64, 128];

/// One analog MVM integrates for `t_int_ns` regardless of matrix size
/// (the crossbar computes all columns in parallel); a batch of `t`
/// tokens is `t` sequential integrations on the same tile.
pub fn aimc_latency_ns(t_tokens: usize, t_int_ns: f64) -> f64 {
    t_tokens as f64 * t_int_ns
}

/// Per-batch hand-off cost AIMC→PMCA that cannot be hidden (results of
/// the *current* batch must land before its LoRA fuse can finish).
pub fn handoff_ns(w: &LoraWorkload, cluster: &SnitchCluster) -> f64 {
    cluster.dma_ns(crate::pmca::kernels::FP16_BYTES * w.t * w.n)
}

#[derive(Clone, Copy, Debug)]
pub struct PipelineLatency {
    /// Per-batch AIMC stage latency (ns).
    pub aimc_ns: f64,
    /// Per-batch PMCA stage latency (ns).
    pub pmca_ns: f64,
    /// Number of token batches for the sequence.
    pub n_batches: usize,
    /// Standalone latency for the full sequence including pipeline fill
    /// and drain (ns) — what a single isolated layer would cost.
    pub total_ns: f64,
    /// Steady-state latency (ns): drain overlaps the *next* layer's AIMC
    /// stage when the whole network is pipelined, so per-layer cost is
    /// n_batches·max(stages) + the un-hideable hand-off. This is the
    /// accounting under which Fig. 4c reports few-percent overheads.
    pub steady_ns: f64,
    /// No-LoRA baseline (AIMC only) for the same sequence (ns).
    pub baseline_ns: f64,
}

impl PipelineLatency {
    /// Fractional latency overhead vs the pure-AIMC baseline in the
    /// network-pipelined steady state (Fig. 4c).
    pub fn overhead(&self) -> f64 {
        self.steady_ns / self.baseline_ns - 1.0
    }

    /// Overhead for an isolated layer (fill + drain included).
    pub fn overhead_standalone(&self) -> f64 {
        self.total_ns / self.baseline_ns - 1.0
    }

    pub fn ratio(&self) -> f64 {
        self.pmca_ns / self.aimc_ns
    }
}

/// Two-stage pipeline over a sequence of `seq_len` tokens processed in
/// batches of `w.t`: steady-state period is max(stage latencies); the
/// pipe fills with the first AIMC batch and drains with the last PMCA
/// batch (plus the un-hideable hand-off).
pub fn pipeline_latency(
    w: &LoraWorkload,
    t_int_ns: f64,
    seq_len: usize,
    cluster: &SnitchCluster,
    engine: &RedMulE,
) -> PipelineLatency {
    // a degenerate empty sequence still costs one pipeline pass — the
    // serving scheduler may probe fill 0 shapes and must not underflow
    let n_batches = seq_len.div_ceil(w.t).max(1);
    let aimc_ns = aimc_latency_ns(w.t, t_int_ns);
    let pmca_ns = w.latency_ns(cluster, engine);
    let period = aimc_ns.max(pmca_ns);
    let handoff = handoff_ns(w, cluster);
    let total_ns = aimc_ns + handoff + period * (n_batches - 1) as f64 + pmca_ns;
    PipelineLatency {
        aimc_ns,
        pmca_ns,
        n_batches,
        total_ns,
        steady_ns: period * n_batches as f64 + handoff,
        baseline_ns: seq_len as f64 * t_int_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (SnitchCluster, RedMulE) {
        (SnitchCluster::default(), RedMulE::default())
    }

    #[test]
    fn aimc_latency_is_linear_in_tokens() {
        assert_eq!(aimc_latency_ns(128, 128.0), 16384.0);
        assert_eq!(aimc_latency_ns(8, 512.0), 4096.0);
    }

    #[test]
    fn pipeline_beats_serial_execution() {
        let (c, e) = env();
        let w = LoraWorkload { m: 512, n: 128, r: 8, t: 32 };
        let p = pipeline_latency(&w, 256.0, 320, &c, &e);
        let serial = (p.aimc_ns + p.pmca_ns) * p.n_batches as f64;
        assert!(p.total_ns < serial);
    }

    #[test]
    fn balanced_stages_give_small_overhead() {
        // Fig. 4c's claim: when AIMC ~ PMCA, LoRA adds only a few percent
        // in the network-pipelined steady state.
        let (c, e) = env();
        let w = LoraWorkload { m: 128, n: 128, r: 8, t: 64 };
        let p = pipeline_latency(&w, 128.0, 320, &c, &e);
        assert!(
            p.ratio() > 0.5 && p.ratio() < 1.1,
            "expected near-balance, ratio={}",
            p.ratio()
        );
        assert!(p.overhead() < 0.10, "overhead={}", p.overhead());
        // standalone (fill+drain) must be strictly worse
        assert!(p.overhead_standalone() > p.overhead());
    }

    #[test]
    fn unbalanced_pmca_dominates_overhead() {
        let (c, e) = env();
        // huge LoRA work per batch vs fast tiles
        let w = LoraWorkload { m: 512, n: 128, r: 8, t: 128 };
        let p = pipeline_latency(&w, 128.0, 320, &c, &e);
        assert!(p.ratio() > 1.5);
        assert!(p.overhead() > 0.5);
    }

    #[test]
    fn n_batches_rounds_up() {
        let (c, e) = env();
        let w = LoraWorkload { m: 128, n: 128, r: 8, t: 64 };
        let p = pipeline_latency(&w, 128.0, 320, &c, &e);
        assert_eq!(p.n_batches, 5);
    }
}
