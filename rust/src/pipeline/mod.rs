//! AIMC ⇄ PMCA pipeline scheduler (the paper's hybrid execution model).
//!
//! While tile `i`'s batch of `t` tokens integrates on the AIMC crossbar,
//! the PMCA computes the LoRA path for batch `i−1`; when latencies are
//! balanced the LoRA adapters add almost no end-to-end time (Fig. 4c:
//! ≤ 2.7 % on the 512×128 layer, ≤ 4.2 % on 128×128).
//!
//! * [`schedule`] — latency of AIMC tiles, the software pipeline, and
//!   the no-LoRA baseline.
//! * [`balance`]  — pick the token-parallelism `t` that balances the
//!   two engines (Fig. 4a) subject to the TCDM capacity (Fig. 4b).

pub mod balance;
pub mod schedule;
