//! Latency balancing: choose the token parallelism `t` that best
//! matches PMCA latency to AIMC latency (Fig. 4a) without exceeding the
//! TCDM (Fig. 4b), then report the end-to-end overhead (Fig. 4c).

use crate::pmca::cluster::SnitchCluster;
use crate::pmca::kernels::LoraWorkload;
use crate::pmca::redmule::RedMulE;
use crate::pmca::tcdm;

use super::schedule::{pipeline_latency, PipelineLatency, TOKEN_PARALLELISM};

#[derive(Clone, Copy, Debug)]
pub struct BalancePoint {
    pub t: usize,
    pub latency: PipelineLatency,
    pub tcdm_kib: f64,
    pub fits_tcdm: bool,
}

impl BalancePoint {
    /// Fractional steady-state overhead vs the pure-AIMC baseline at
    /// this operating point (the Fig. 4c quantity; see
    /// [`PipelineLatency::overhead`]).
    pub fn overhead(&self) -> f64 {
        self.latency.overhead()
    }
}

/// Evaluate every candidate `t` for a layer at one integration time.
pub fn sweep(
    m: usize,
    n: usize,
    r: usize,
    t_int_ns: f64,
    seq_len: usize,
    cluster: &SnitchCluster,
    engine: &RedMulE,
) -> Vec<BalancePoint> {
    let layer = LoraWorkload::new(m, n, r, 0);
    TOKEN_PARALLELISM
        .iter()
        .map(|&t| {
            let w = layer.with_tokens(t);
            BalancePoint {
                t,
                latency: pipeline_latency(&w, t_int_ns, seq_len, cluster, engine),
                tcdm_kib: tcdm::footprint(&w).kib(),
                fits_tcdm: tcdm::fits(&w, cluster),
            }
        })
        .collect()
}

/// Sweep + [`best`] in one call — the shape both the Fig. 4 experiment
/// and the serving scheduler consume.
pub fn best_point(
    m: usize,
    n: usize,
    r: usize,
    t_int_ns: f64,
    seq_len: usize,
    cluster: &SnitchCluster,
    engine: &RedMulE,
) -> BalancePoint {
    best(&sweep(m, n, r, t_int_ns, seq_len, cluster, engine))
}

/// The paper's balancing objective: minimise end-to-end latency; prefer
/// points that fit the TCDM (spilling costs extra SRAM traffic).
pub fn best(points: &[BalancePoint]) -> BalancePoint {
    let fitting: Vec<&BalancePoint> = points.iter().filter(|p| p.fits_tcdm).collect();
    let pool: Vec<&BalancePoint> = if fitting.is_empty() {
        points.iter().collect()
    } else {
        fitting
    };
    **pool
        .iter()
        .min_by(|a, b| a.latency.total_ns.total_cmp(&b.latency.total_ns))
        .expect("non-empty sweep")
}

/// Sweep, commit to the winning balance point, and tabulate the modeled
/// steady-state latency of serving `b · seq_len` tokens at that point
/// for every fill `b` in `1..=max_batch`.
///
/// This is the ONE cost table both serving-side consumers share:
/// [`crate::serve::sched::BatchScheduler`] reads it on the batch-close
/// hot path and [`crate::serve::hal::CostModel`] reads it for
/// task→backend placement, so a backend's routing cost and its
/// scheduler's close decisions can never disagree about the hardware
/// model.
pub fn latency_table(
    m: usize,
    n: usize,
    r: usize,
    t_int_ns: f64,
    seq_len: usize,
    max_batch: usize,
    cluster: &SnitchCluster,
    engine: &RedMulE,
) -> (BalancePoint, Vec<f64>) {
    let seq_len = seq_len.max(1);
    let max_batch = max_batch.max(1);
    let balance = best(&sweep(m, n, r, t_int_ns, seq_len, cluster, engine));
    let w = LoraWorkload::new(m, n, r, balance.t);
    let table = (1..=max_batch)
        .map(|b| pipeline_latency(&w, t_int_ns, b * seq_len, cluster, engine).steady_ns)
        .collect();
    (balance, table)
}

/// The fills a rate-driven consumer of `table` can ever commit to:
/// the per-request-latency frontier.
///
/// Fill `b` is on the frontier iff its per-request latency
/// `table[b-1] / b` strictly beats every smaller fill — which is
/// exactly the image of the "smallest fill that keeps up" rule
/// ([`crate::serve::sched::BatchScheduler::target_fill`],
/// [`crate::serve::hal::CostModel::sustainable_fill`]) over all
/// arrival rates — plus the maximum fill, the fallback when no
/// tabulated fill sustains the rate. Sorted ascending; this is the
/// set `ServerBuilder::build` AOT shape-specializes each worker's
/// forward executor for (`runtime::compile`).
pub fn frontier_fills(table: &[f64]) -> Vec<usize> {
    let mut fills = Vec::new();
    let mut best = f64::INFINITY;
    for (i, &ns) in table.iter().enumerate() {
        let per_req = ns / (i + 1) as f64;
        if per_req < best {
            best = per_req;
            fills.push(i + 1);
        }
    }
    if !table.is_empty() && fills.last() != Some(&table.len()) {
        fills.push(table.len());
    }
    fills
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (SnitchCluster, RedMulE) {
        (SnitchCluster::default(), RedMulE::default())
    }

    #[test]
    fn latency_table_matches_manual_sweep() {
        let (c, e) = env();
        let (b, table) = latency_table(128, 128, 8, 256.0, 320, 8, &c, &e);
        assert_eq!(b.t, best(&sweep(128, 128, 8, 256.0, 320, &c, &e)).t);
        assert_eq!(table.len(), 8);
        let w = LoraWorkload::new(128, 128, 8, b.t);
        for (i, &ns) in table.iter().enumerate() {
            let want = pipeline_latency(&w, 256.0, (i + 1) * 320, &c, &e).steady_ns;
            assert_eq!(ns, want, "fill {}", i + 1);
        }
        // latency grows with fill
        for i in 1..table.len() {
            assert!(table[i] > table[i - 1]);
        }
    }

    #[test]
    fn frontier_is_image_of_smallest_sustainable_fill() {
        // per-request: 100, 75, 80, 90, 160 — fill 2 dominates 3 and 4
        let table = vec![100.0, 150.0, 240.0, 360.0, 800.0];
        assert_eq!(frontier_fills(&table), vec![1, 2, 5]);
        // exhaustively: the target-fill rule over a rate sweep reaches
        // exactly the frontier fills, nothing else
        let target = |gap: f64| {
            (1..=table.len())
                .find(|&b| table[b - 1] / b as f64 <= gap)
                .unwrap_or(table.len())
        };
        let mut image: Vec<usize> = Vec::new();
        for gap in [5.0, 50.0, 74.0, 75.0, 76.0, 79.0, 80.0, 85.0, 100.0, 1e12] {
            let b = target(gap);
            if !image.contains(&b) {
                image.push(b);
            }
        }
        image.sort_unstable();
        assert_eq!(frontier_fills(&table), image);
    }

    #[test]
    fn frontier_edge_cases() {
        assert_eq!(frontier_fills(&[]), Vec::<usize>::new());
        assert_eq!(frontier_fills(&[42.0]), vec![1]);
        // strictly sublinear growth: every fill improves per-request
        assert_eq!(frontier_fills(&[100.0, 150.0, 180.0]), vec![1, 2, 3]);
        // the real model's table is on its own frontier at every fill
        // up to where overhead amortizes; max fill is always present
        let (_, table) = latency_table(
            128,
            128,
            8,
            256.0,
            320,
            8,
            &SnitchCluster::default(),
            &RedMulE::default(),
        );
        let fills = frontier_fills(&table);
        assert_eq!(fills.first(), Some(&1));
        assert_eq!(fills.last(), Some(&8), "max fill is always committed");
        for w in fills.windows(2) {
            if w[1] == table.len() {
                // the max fill may be the appended unsustainable-rate
                // fallback rather than a frontier point of its own
                continue;
            }
            assert!(
                table[w[1] - 1] / w[1] as f64 < table[w[0] - 1] / w[0] as f64,
                "non-max frontier fills must strictly improve per-request latency"
            );
        }
    }

    /// The calibration anchor for the whole PMCA model: reproduce the
    /// PMCA/AIMC latency ratios the paper reports in Fig. 4a at the
    /// *paper's own balance points*.
    #[test]
    fn fig4a_ratio_calibration() {
        let (c, e) = env();
        // (m, n, t_int, t, paper_ratio)
        let anchors = [
            (128usize, 128usize, 128.0f64, 128usize, 1.04f64),
            (128, 128, 256.0, 8, 0.63),
            (128, 128, 512.0, 8, 0.32),
            (512, 128, 128.0, 128, 2.57),
            (512, 128, 256.0, 128, 1.29),
            (512, 128, 512.0, 8, 0.70),
        ];
        for (m, n, t_int, t, paper) in anchors {
            let w = LoraWorkload { m, n, r: 8, t };
            let p = pipeline_latency(&w, t_int, 320, &c, &e);
            let ratio = p.ratio();
            assert!(
                (ratio - paper).abs() / paper < 0.15,
                "({m}x{n}, {t_int}ns, t={t}): model ratio {ratio:.2} vs paper {paper:.2}"
            );
        }
    }

    #[test]
    fn best_prefers_tcdm_fitting_points() {
        let (c, e) = env();
        let pts = sweep(512, 128, 8, 128.0, 320, &c, &e);
        let b = best(&pts);
        assert!(b.fits_tcdm, "picked t={} which spills TCDM", b.t);
    }

    #[test]
    fn longer_integration_prefers_fewer_tokens() {
        // Slow tiles leave the PMCA idle; balance favours small t so
        // overhead is amortised... larger t always helps AIMC-bound
        // configs equally, so check the *ratio* moves toward balance.
        let (c, e) = env();
        let r128 = best(&sweep(128, 128, 8, 128.0, 320, &c, &e));
        let r512 = best(&sweep(128, 128, 8, 512.0, 320, &c, &e));
        assert!(r512.latency.ratio() < r128.latency.ratio());
    }

    #[test]
    fn fig4c_overhead_at_balance_is_small() {
        // Paper: at well-balanced operating points the LoRA overhead is
        // a few percent (<=2.72% for 512x128, <=4.2% for 128x128). Where
        // the PMCA is the bottleneck (512x128 at 128 ns) the paper itself
        // reports PMCA-dominance, so only balanced points are asserted.
        let (c, e) = env();
        for (m, n) in [(512usize, 128usize), (128, 128)] {
            let mut best_overhead = f64::INFINITY;
            for t_int in [128.0, 256.0, 512.0] {
                let b = best(&sweep(m, n, 8, t_int, 320, &c, &e));
                if b.latency.ratio() <= 1.05 {
                    assert!(
                        b.latency.overhead() < 0.10,
                        "{m}x{n}@{t_int}: balanced but overhead {:.3}",
                        b.latency.overhead()
                    );
                }
                best_overhead = best_overhead.min(b.latency.overhead());
            }
            // some integration time must yield the paper's few-percent regime
            assert!(best_overhead < 0.05, "{m}x{n}: best overhead {best_overhead:.3}");
        }
    }
}
