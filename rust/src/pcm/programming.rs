//! State-dependent programming (write) noise.
//!
//! Iterative program-and-verify on PCM leaves a residual error whose
//! standard deviation depends on the target state. Joshi et al. 2020
//! fit a quadratic on the normalised target conductance; the same shape
//! is used by AIHWKIT's `PCMLikeNoiseModel`:
//!
//! σ_prog(g_t) = max(c₀ + c₁·(g_t/g_max) + c₂·(g_t/g_max)², 0)  [µS]

use super::PcmModel;
use crate::util::rng::Pcg64;

/// σ_prog for one target conductance (µS).
#[inline]
pub fn prog_sigma(model: &PcmModel, g_target: f32) -> f32 {
    let g_rel = (g_target / model.g_max).clamp(0.0, 1.0);
    let [c0, c1, c2] = model.prog_coeff;
    (c0 + c1 * g_rel + c2 * g_rel * g_rel).max(0.0) * model.noise_scale
}

/// Program a buffer of target conductances in place, adding write noise
/// and clamping to the physical range [0, 1.2·g_max] (slight overshoot
/// is physical; negative conductance is not).
pub fn apply_programming_noise(model: &PcmModel, g: &mut [f32], rng: &mut Pcg64) {
    let hi = 1.2 * model.g_max;
    for v in g.iter_mut() {
        let sigma = prog_sigma(model, *v);
        if sigma > 0.0 {
            *v = (*v + sigma * rng.normal_f32()).clamp(0.0, hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_is_state_dependent_and_positive() {
        let m = PcmModel::default();
        let lo = prog_sigma(&m, 0.0);
        let peak = prog_sigma(&m, m.g_max * 0.8376); // vertex of the quadratic
        let hi = prog_sigma(&m, m.g_max);
        assert!(lo > 0.0 && peak > 0.0 && hi > 0.0);
        // the Joshi'20 fit peaks at g_rel = c1/(2|c2|) ~ 0.84, interior
        assert!(peak > lo && peak > hi);
    }

    #[test]
    fn noise_scale_zero_disables() {
        let m = PcmModel::ideal();
        let mut g = vec![1.0f32, 10.0, 20.0];
        let orig = g.clone();
        apply_programming_noise(&m, &mut g, &mut Pcg64::new(1));
        assert_eq!(g, orig);
    }

    #[test]
    fn programmed_values_stay_physical() {
        let m = PcmModel::default();
        let mut g = vec![0.0f32; 10_000];
        for (i, v) in g.iter_mut().enumerate() {
            *v = (i % 26) as f32;
        }
        apply_programming_noise(&m, &mut g, &mut Pcg64::new(2));
        assert!(g.iter().all(|&v| (0.0..=1.2 * m.g_max).contains(&v)));
    }

    #[test]
    fn empirical_sigma_matches_model() {
        let m = PcmModel::default();
        let target = 12.5f32;
        let n = 50_000;
        let mut g = vec![target; n];
        apply_programming_noise(&m, &mut g, &mut Pcg64::new(3));
        let mean = g.iter().map(|x| *x as f64).sum::<f64>() / n as f64;
        let sd = (g.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / n as f64).sqrt();
        let expect = prog_sigma(&m, target) as f64;
        assert!((sd - expect).abs() < 0.05 * expect, "sd={sd} expect={expect}");
    }
}
