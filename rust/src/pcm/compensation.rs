//! Global drift compensation (GDC).
//!
//! The paper mitigates temporal drift with the scheme of Joshi et al.
//! 2020 (its ref. 22): periodically read the summed response of each
//! layer's devices to a calibration input and re-scale the digital
//! output by the ratio to the post-programming reference. One scalar
//! per programmed tensor — cheap, and exactly restores the *mean*
//! conductance scale (the stochastic spread remains, which is what the
//! LoRA adapters then compensate).

use super::{drift, PcmModel, ProgrammedTensor};

/// Reference read: Σ(g⁺ + g⁻) at programming time (t = 0, i.e. t₀).
pub fn gdc_reference(tensor_gp: &[f32], tensor_gm: &[f32]) -> f64 {
    tensor_gp.iter().map(|&v| v as f64).sum::<f64>() + tensor_gm.iter().map(|&v| v as f64).sum::<f64>()
}

/// Compensation factor α = S_ref / S(t) from a current read.
pub fn gdc_factor(_model: &PcmModel, tensor: &ProgrammedTensor, gp_now: &[f32], gm_now: &[f32]) -> f32 {
    let s_now = gdc_reference(gp_now, gm_now);
    if s_now <= f64::EPSILON {
        return 1.0;
    }
    (tensor.gdc_reference / s_now) as f32
}

// ---------------------------------------------------------------------------
// Residual decay after compensation
// ---------------------------------------------------------------------------

/// Device-to-device dispersion of the drift factor at a representative
/// relative conductance `g_rel` (0‥1): the effective σ of the per-device
/// drift exponents, scaled by the model's global noise knob.
pub fn drift_dispersion(model: &PcmModel, g_rel: f32) -> f64 {
    (model.noise_scale * drift::nu_std(model, g_rel * model.g_max)) as f64
}

/// Predicted *post-GDC* accuracy-relevant weight decay at drift age
/// `t_seconds`, as a fraction in [0, 1).
///
/// GDC exactly restores the mean conductance scale, so what erodes a
/// deployed adapter's accuracy is the device-to-device *spread* of the
/// drift factor `exp(−ν·ln((t+t₀)/t₀))`. For ν ~ N(μ_ν, σ_ν) the
/// relative residual grows like `σ_ν·ln((t+t₀)/t₀)`; this model maps it
/// into a bounded fraction via `1 − exp(−σ_ν·ln r)` — zero at t = 0,
/// strictly monotone in t, saturating at 1. The serving refresh policy
/// (`serve::refresh`) compares it against a per-task tolerance.
pub fn residual_decay(model: &PcmModel, g_rel: f32, t_seconds: f64) -> f64 {
    if t_seconds <= 0.0 {
        return 0.0;
    }
    let s = drift_dispersion(model, g_rel);
    let log_ratio = ((t_seconds + model.t0) / model.t0).ln();
    1.0 - (-s * log_ratio).exp()
}

/// Inverse of [`residual_decay`]: the drift age (seconds) at which the
/// predicted decay first reaches `decay`. Returns 0 for a non-positive
/// target and `f64::INFINITY` when the model never decays that far
/// (ideal substrate, or `decay ≥ 1`).
pub fn residual_decay_inverse(model: &PcmModel, g_rel: f32, decay: f64) -> f64 {
    if decay <= 0.0 {
        return 0.0;
    }
    let s = drift_dispersion(model, g_rel);
    if s <= 0.0 || decay >= 1.0 {
        return f64::INFINITY;
    }
    let log_ratio = -(1.0 - decay).ln() / s;
    model.t0 * (log_ratio.exp() - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aimc::mapping::program_tensor;
    use crate::util::rng::Pcg64;

    #[test]
    fn factor_is_one_when_nothing_drifted() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(1);
        let mut w = vec![0f32; 256];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 16, 16, 3.0, &mut rng);
        let a = gdc_factor(&model, &t, &t.g_plus, &t.g_minus);
        assert!((a - 1.0).abs() < 1e-6);
    }

    #[test]
    fn factor_compensates_uniform_decay() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(2);
        let mut w = vec![0f32; 256];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 16, 16, 3.0, &mut rng);
        let gp: Vec<f32> = t.g_plus.iter().map(|v| v * 0.8).collect();
        let gm: Vec<f32> = t.g_minus.iter().map(|v| v * 0.8).collect();
        let a = gdc_factor(&model, &t, &gp, &gm);
        assert!((a - 1.25).abs() < 1e-3, "alpha={a}");
    }

    #[test]
    fn residual_decay_is_zero_at_programming_and_monotone() {
        let m = PcmModel::default();
        assert_eq!(residual_decay(&m, 0.5, 0.0), 0.0);
        let mut last = 0.0;
        for secs in [60.0, 3600.0, 86_400.0, 2_592_000.0, 315_360_000.0] {
            let d = residual_decay(&m, 0.5, secs);
            assert!(d > last, "decay must grow with drift age: {d} vs {last}");
            assert!(d < 1.0);
            last = d;
        }
    }

    #[test]
    fn residual_decay_inverse_round_trips() {
        let m = PcmModel::default();
        for tol in [0.01, 0.05, 0.2, 0.6] {
            let t = residual_decay_inverse(&m, 0.5, tol);
            assert!(t.is_finite() && t > 0.0);
            let d = residual_decay(&m, 0.5, t);
            assert!((d - tol).abs() < 1e-9, "decay({t}) = {d}, want {tol}");
        }
        assert_eq!(residual_decay_inverse(&m, 0.5, 0.0), 0.0);
        assert_eq!(residual_decay_inverse(&m, 0.5, 1.0), f64::INFINITY);
    }

    #[test]
    fn ideal_substrate_never_decays() {
        let m = PcmModel::ideal();
        assert_eq!(residual_decay(&m, 0.5, 315_360_000.0), 0.0);
        assert_eq!(residual_decay_inverse(&m, 0.5, 0.05), f64::INFINITY);
    }

    #[test]
    fn zero_read_degrades_gracefully() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(3);
        let mut w = vec![0f32; 64];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 8, 8, 3.0, &mut rng);
        let z = vec![0f32; 64];
        assert_eq!(gdc_factor(&model, &t, &z, &z), 1.0);
    }
}
