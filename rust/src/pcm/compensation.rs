//! Global drift compensation (GDC).
//!
//! The paper mitigates temporal drift with the scheme of Joshi et al.
//! 2020 (its ref. 22): periodically read the summed response of each
//! layer's devices to a calibration input and re-scale the digital
//! output by the ratio to the post-programming reference. One scalar
//! per programmed tensor — cheap, and exactly restores the *mean*
//! conductance scale (the stochastic spread remains, which is what the
//! LoRA adapters then compensate).

use super::{PcmModel, ProgrammedTensor};

/// Reference read: Σ(g⁺ + g⁻) at programming time (t = 0, i.e. t₀).
pub fn gdc_reference(tensor_gp: &[f32], tensor_gm: &[f32]) -> f64 {
    tensor_gp.iter().map(|&v| v as f64).sum::<f64>() + tensor_gm.iter().map(|&v| v as f64).sum::<f64>()
}

/// Compensation factor α = S_ref / S(t) from a current read.
pub fn gdc_factor(_model: &PcmModel, tensor: &ProgrammedTensor, gp_now: &[f32], gm_now: &[f32]) -> f32 {
    let s_now = gdc_reference(gp_now, gm_now);
    if s_now <= f64::EPSILON {
        return 1.0;
    }
    (tensor.gdc_reference / s_now) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aimc::mapping::program_tensor;
    use crate::util::rng::Pcg64;

    #[test]
    fn factor_is_one_when_nothing_drifted() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(1);
        let mut w = vec![0f32; 256];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 16, 16, 3.0, &mut rng);
        let a = gdc_factor(&model, &t, &t.g_plus, &t.g_minus);
        assert!((a - 1.0).abs() < 1e-6);
    }

    #[test]
    fn factor_compensates_uniform_decay() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(2);
        let mut w = vec![0f32; 256];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 16, 16, 3.0, &mut rng);
        let gp: Vec<f32> = t.g_plus.iter().map(|v| v * 0.8).collect();
        let gm: Vec<f32> = t.g_minus.iter().map(|v| v * 0.8).collect();
        let a = gdc_factor(&model, &t, &gp, &gm);
        assert!((a - 1.25).abs() < 1e-3, "alpha={a}");
    }

    #[test]
    fn zero_read_degrades_gracefully() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(3);
        let mut w = vec![0f32; 64];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 8, 8, 3.0, &mut rng);
        let z = vec![0f32; 64];
        assert_eq!(gdc_factor(&model, &t, &z, &z), 1.0);
    }
}
