//! Statistical Phase-Change-Memory device model.
//!
//! Implements the three temporal non-idealities the paper evaluates
//! against (Methods — Training and Inference Details), with the
//! functional forms published for IBM's doped-Ge₂Sb₂Te₅ PCM arrays
//! (Nandakumar et al. 2019; Joshi et al. 2020 — the same model family
//! AIHWKIT's `PCMLikeNoiseModel` calibrates to measurements from a
//! million-device chip):
//!
//! 1. **Programming noise** — state-dependent write error,
//!    `σ_prog(g)` a quadratic polynomial in the target conductance
//!    ([`programming`]).
//! 2. **Conductance drift** — `g(t) = g_prog · ((t+t₀)/t₀)^(−ν)` with a
//!    per-device, state-dependent drift exponent ν ([`drift`]).
//! 3. **1/f read noise** — `σ_read(t) = g·Q_s·√ln((t+t_r)/(2 t_r))`
//!    ([`read_noise`]).
//!
//! Plus the paper's mitigation: **global drift compensation**
//! ([`compensation`]) — a per-layer scalar re-scale estimated from a
//! calibration read, exactly as in Joshi et al. 2020 (paper ref. 22).
//!
//! Exact constants in this offline image cannot be re-fit to hardware;
//! values follow the published shapes (DESIGN.md §Substitutions). The
//! paper's *training* abstraction (a 6.7 % effective Gaussian) is
//! independent of this module and lives in the L2 graphs.

pub mod compensation;
pub mod drift;
pub mod programming;
pub mod read_noise;

use crate::util::rng::Pcg64;

/// Device-physics constants. `Default` matches the paper's setup
/// (G_max = 25 µS, drift reference t₀ = 20 s).
#[derive(Clone, Debug)]
pub struct PcmModel {
    /// Maximum programmable conductance, µS.
    pub g_max: f32,
    /// Drift reference time (first read after programming), seconds.
    pub t0: f64,
    /// Single read duration for the 1/f noise integral, seconds.
    pub t_read: f64,
    /// Programming-noise polynomial σ(g_rel) = c0 + c1·g_rel + c2·g_rel².
    pub prog_coeff: [f32; 3],
    /// Drift-exponent statistics bounds (see [`drift`]).
    pub nu_clip: (f32, f32),
    /// Read-noise amplitude cap for Q_s.
    pub q_s_max: f32,
    /// Scales all stochastic amplitudes (0 disables every non-ideality —
    /// used by tests and the "digital" baselines).
    pub noise_scale: f32,
}

impl Default for PcmModel {
    fn default() -> Self {
        PcmModel {
            g_max: 25.0,
            t0: 20.0,
            t_read: 250e-9,
            // Joshi et al. 2020, Supplementary eq. (3), µS units on a
            // 25 µS-normalised state axis.
            prog_coeff: [0.26348, 1.9650, -1.1731],
            nu_clip: (0.0, 0.1),
            q_s_max: 0.2,
            noise_scale: 1.0,
        }
    }
}

impl PcmModel {
    /// Ideal (noise-free) model for digital baselines.
    pub fn ideal() -> Self {
        PcmModel {
            noise_scale: 0.0,
            ..Default::default()
        }
    }
}

/// One weight tensor programmed onto PCM devices in the paper's
/// differential configuration: `w ∝ g⁺ − g⁻`. Created by
/// [`crate::aimc::mapping::program_tensor`]; evaluated at a drift time by
/// [`read_tensor`].
#[derive(Clone, Debug)]
pub struct ProgrammedTensor {
    pub rows: usize,
    pub cols: usize,
    /// Post-programming (noisy) conductances, row-major, µS.
    pub g_plus: Vec<f32>,
    pub g_minus: Vec<f32>,
    /// Per-device drift exponents (sampled once at programming).
    pub nu_plus: Vec<f32>,
    pub nu_minus: Vec<f32>,
    /// Per-output-channel weight→conductance scale (µS per unit weight).
    pub col_scale: Vec<f32>,
    /// Calibration read Σg at t₀ for global drift compensation.
    pub gdc_reference: f64,
}

impl ProgrammedTensor {
    pub fn n_devices(&self) -> usize {
        2 * self.rows * self.cols
    }
}

/// Evaluate the effective weight matrix seen by the tile at drift time
/// `t_seconds`, applying drift, read noise, and (optionally) global
/// drift compensation. This is the drift-evaluation hot path: one fused
/// pass per device array (drift ∘ read-noise), then the differential
/// weight reconstruction — no intermediate allocations beyond the two
/// conductance buffers the GDC read needs (EXPERIMENTS.md §Perf,
/// iteration 2; the original 3-pass version was 2.3× slower).
pub fn read_tensor(
    model: &PcmModel,
    tensor: &ProgrammedTensor,
    t_seconds: f64,
    compensate: bool,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let n = tensor.rows * tensor.cols;
    let mut gp = vec![0f32; n];
    let mut gm = vec![0f32; n];
    read_devices(model, &tensor.g_plus, &tensor.nu_plus, t_seconds, rng, &mut gp);
    read_devices(model, &tensor.g_minus, &tensor.nu_minus, t_seconds, rng, &mut gm);

    let alpha = if compensate {
        compensation::gdc_factor(model, tensor, &gp, &gm)
    } else {
        1.0
    };

    let mut w = vec![0f32; n];
    for r in 0..tensor.rows {
        let base = r * tensor.cols;
        for c in 0..tensor.cols {
            let i = base + c;
            w[i] = alpha * (gp[i] - gm[i]) / tensor.col_scale[c];
        }
    }
    w
}

/// Fused drift + read-noise evaluation of one device array. The shared
/// per-read factors (drift log-ratio, 1/f time factor) are hoisted; the
/// state-dependent q_s power law is evaluated per device on the drifted
/// conductance, exactly as the 2-pass reference implementation in
/// [`drift`]/[`read_noise`] (property-tested equivalent in the module
/// tests below).
fn read_devices(
    model: &PcmModel,
    g_prog: &[f32],
    nu: &[f32],
    t_seconds: f64,
    rng: &mut Pcg64,
    out: &mut [f32],
) {
    if model.noise_scale == 0.0 {
        // ideal model: drift/noise disabled entirely
        if t_seconds <= 0.0 {
            out.copy_from_slice(g_prog);
            return;
        }
        drift::apply_drift(model, g_prog, nu, t_seconds, out);
        return;
    }
    let log_ratio = ((t_seconds + model.t0) / model.t0).ln() as f32;
    let t = t_seconds.max(model.t_read);
    let time_factor =
        (((t + model.t_read) / (2.0 * model.t_read)).ln()).sqrt() as f32 * model.noise_scale;
    let inv_gmax = 1.0 / model.g_max;
    for i in 0..g_prog.len() {
        // drift
        let g = if t_seconds > 0.0 {
            g_prog[i] * (-nu[i] * log_ratio).exp()
        } else {
            g_prog[i]
        };
        // 1/f read noise at the drifted state
        let g_rel = (g * inv_gmax).max(1e-6);
        let q_s = (0.0088 / g_rel.powf(0.65)).min(model.q_s_max);
        let sigma = g * q_s * time_factor;
        // skip the draw for zero-conductance devices, matching the
        // reference passes' RNG consumption exactly
        out[i] = if sigma > 0.0 {
            (g + sigma * rng.normal_f32()).max(0.0)
        } else {
            g
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aimc::mapping::program_tensor;

    fn toy_tensor(seed: u64) -> (PcmModel, ProgrammedTensor, Vec<f32>) {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(seed);
        let mut w = vec![0f32; 64 * 32];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 64, 32, 3.0, &mut rng);
        (model, t, w)
    }

    #[test]
    fn read_at_zero_approximates_target() {
        let (model, t, w) = toy_tensor(1);
        let mut rng = Pcg64::new(2);
        let got = read_tensor(&model, &t, 0.0, true, &mut rng);
        let err: f64 = got
            .iter()
            .zip(&w)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / w.len() as f64;
        let scale: f64 = w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len() as f64;
        assert!(err < 0.25 * scale, "mean err {err} vs scale {scale}");
    }

    #[test]
    fn ideal_model_is_exact_at_t0() {
        let model = PcmModel::ideal();
        let mut rng = Pcg64::new(3);
        let mut w = vec![0f32; 128];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let t = program_tensor(&model, &w, 16, 8, 0.0, &mut rng);
        let got = read_tensor(&model, &t, 0.0, false, &mut rng);
        for (a, b) in got.iter().zip(&w) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn drift_decays_magnitude_without_compensation() {
        let (model, t, _) = toy_tensor(4);
        let mut rng = Pcg64::new(5);
        let w0 = read_tensor(&model, &t, 0.0, false, &mut rng);
        let wy = read_tensor(&model, &t, 365.0 * 86400.0, false, &mut rng);
        let m0: f64 = w0.iter().map(|x| x.abs() as f64).sum();
        let my: f64 = wy.iter().map(|x| x.abs() as f64).sum();
        assert!(my < 0.95 * m0, "1-year drift should shrink weights: {my} vs {m0}");
    }

    #[test]
    fn compensation_recovers_scale() {
        let (model, t, _) = toy_tensor(6);
        let mut rng = Pcg64::new(7);
        let w_raw = read_tensor(&model, &t, 365.0 * 86400.0, false, &mut rng);
        let w_gdc = read_tensor(&model, &t, 365.0 * 86400.0, true, &mut rng);
        let m_raw: f64 = w_raw.iter().map(|x| x.abs() as f64).sum();
        let m_gdc: f64 = w_gdc.iter().map(|x| x.abs() as f64).sum();
        let w0 = read_tensor(&model, &t, 0.0, false, &mut rng);
        let m0: f64 = w0.iter().map(|x| x.abs() as f64).sum();
        assert!((m_gdc - m0).abs() < (m_raw - m0).abs(), "GDC should restore magnitude");
    }

    #[test]
    fn fused_read_matches_reference_passes() {
        // the fused hot path must be bit-identical to the two-pass
        // reference (drift then read-noise), including RNG consumption
        let model = PcmModel::default();
        let mut rng = Pcg64::new(11);
        let mut g = vec![0f32; 600];
        rng.fill_normal(&mut g, 10.0, 6.0);
        for v in g.iter_mut() {
            *v = v.clamp(0.0, 25.0); // includes exact zeros
        }
        let nu = drift::sample_nu(&model, &g, &mut rng);
        for secs in [0.0, 3600.0, 31_536_000.0] {
            let mut reference = vec![0f32; g.len()];
            drift::apply_drift(&model, &g, &nu, secs, &mut reference);
            let mut r1 = Pcg64::new(99);
            read_noise::apply_read_noise(&model, &mut reference, secs, &mut r1);

            let mut fused = vec![0f32; g.len()];
            let mut r2 = Pcg64::new(99);
            read_devices(&model, &g, &nu, secs, &mut r2, &mut fused);
            for (a, b) in fused.iter().zip(&reference) {
                assert!((a - b).abs() <= 2e-5 * b.abs().max(1.0), "{a} vs {b} @ {secs}s");
            }
        }
    }

    #[test]
    fn longer_drift_means_larger_error() {
        let (model, t, w) = toy_tensor(8);
        let mut errs = vec![];
        for (i, secs) in [0.0, 3600.0, 86400.0 * 30.0, 86400.0 * 3650.0].iter().enumerate() {
            // average over trials to damp read-noise variance
            let mut e = 0.0;
            for trial in 0..5 {
                let mut rng = Pcg64::new(100 + i as u64 * 17 + trial);
                let got = read_tensor(&model, &t, *secs, true, &mut rng);
                e += got
                    .iter()
                    .zip(&w)
                    .map(|(a, b)| ((a - b) * (a - b)) as f64)
                    .sum::<f64>();
            }
            errs.push(e);
        }
        assert!(errs[3] > errs[0], "10y {} should exceed 0s {}", errs[3], errs[0]);
    }
}
