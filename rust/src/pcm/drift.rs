//! Conductance drift: structural relaxation of the amorphous phase.
//!
//! g(t) = g_prog · ((t + t₀)/t₀)^(−ν), with a per-device drift exponent
//! ν drawn once at programming time from a state-dependent normal
//! distribution (lower-conductance = more amorphous = stronger drift),
//! following the measured dependence in Joshi et al. 2020 / AIHWKIT:
//!
//!   μ_ν(g_rel) = clamp(−0.0155·ln(g_rel) + 0.0244, ν_lo, ν_hi)
//!   σ_ν(g_rel) = clamp(−0.0125·ln(g_rel) − 0.0059, 0.008, 0.045)
//!
//! The `(t+t₀)/t₀` form makes t = 0 the programming-time read (factor 1)
//! so the paper's "0 s" column is exactly the post-programming state.

use super::PcmModel;
use crate::util::rng::Pcg64;

/// Mean drift exponent for a programmed conductance.
#[inline]
pub fn nu_mean(model: &PcmModel, g: f32) -> f32 {
    let g_rel = (g / model.g_max).clamp(1e-4, 1.0);
    (-0.0155 * g_rel.ln() + 0.0244).clamp(model.nu_clip.0, model.nu_clip.1)
}

/// Device-to-device spread of the drift exponent.
#[inline]
pub fn nu_std(model: &PcmModel, g: f32) -> f32 {
    let g_rel = (g / model.g_max).clamp(1e-4, 1.0);
    (-0.0125 * g_rel.ln() - 0.0059).clamp(0.008, 0.045)
}

/// Sample per-device drift exponents for programmed conductances.
pub fn sample_nu(model: &PcmModel, g_prog: &[f32], rng: &mut Pcg64) -> Vec<f32> {
    g_prog
        .iter()
        .map(|&g| {
            let nu = nu_mean(model, g) + model.noise_scale * nu_std(model, g) * rng.normal_f32();
            nu.clamp(model.nu_clip.0, model.nu_clip.1)
        })
        .collect()
}

/// Apply drift to programmed conductances, writing drifted values.
pub fn apply_drift(model: &PcmModel, g_prog: &[f32], nu: &[f32], t_seconds: f64, out: &mut [f32]) {
    debug_assert_eq!(g_prog.len(), nu.len());
    debug_assert_eq!(g_prog.len(), out.len());
    if t_seconds <= 0.0 || model.noise_scale == 0.0 {
        out.copy_from_slice(g_prog);
        return;
    }
    // factor = exp(-ν · ln((t+t0)/t0)); hoist the log out of the loop.
    let log_ratio = ((t_seconds + model.t0) / model.t0).ln() as f32;
    for i in 0..g_prog.len() {
        out[i] = g_prog[i] * (-nu[i] * log_ratio).exp();
    }
}

/// Drift-time grid used throughout the paper's tables (0 s … 10 y).
pub const DRIFT_TIMES: [(&str, f64); 7] = [
    ("0s", 0.0),
    ("1h", 3600.0),
    ("1d", 86_400.0),
    ("1w", 604_800.0),
    ("1m", 2_592_000.0),
    ("1y", 31_536_000.0),
    ("10y", 315_360_000.0),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_conductance_drifts_more() {
        let m = PcmModel::default();
        assert!(nu_mean(&m, 1.0) > nu_mean(&m, 25.0));
    }

    #[test]
    fn drift_is_monotone_in_time() {
        let m = PcmModel::default();
        let g = vec![20.0f32; 16];
        let nu = vec![0.05f32; 16];
        let mut a = vec![0f32; 16];
        let mut b = vec![0f32; 16];
        apply_drift(&m, &g, &nu, 3600.0, &mut a);
        apply_drift(&m, &g, &nu, 86_400.0 * 365.0, &mut b);
        assert!(b[0] < a[0] && a[0] < 20.0);
    }

    #[test]
    fn zero_time_is_identity() {
        let m = PcmModel::default();
        let g = vec![5.0f32, 10.0, 20.0];
        let nu = vec![0.08f32; 3];
        let mut out = vec![0f32; 3];
        apply_drift(&m, &g, &nu, 0.0, &mut out);
        assert_eq!(out, g);
    }

    #[test]
    fn ten_year_decay_magnitude_is_plausible() {
        // ν≈0.024 at full conductance: (10y/20s)^-0.024 ≈ 0.66 — weights
        // lose ~1/3 of magnitude over 10 years before compensation.
        let m = PcmModel::default();
        let g = vec![25.0f32];
        let nu = vec![nu_mean(&m, 25.0)];
        let mut out = vec![0f32];
        apply_drift(&m, &g, &nu, 315_360_000.0, &mut out);
        let ratio = out[0] / 25.0;
        assert!((0.4..0.9).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn sampled_nu_within_clip() {
        let m = PcmModel::default();
        let g: Vec<f32> = (0..1000).map(|i| (i % 26) as f32).collect();
        let nu = sample_nu(&m, &g, &mut Pcg64::new(4));
        assert!(nu.iter().all(|&v| (m.nu_clip.0..=m.nu_clip.1).contains(&v)));
    }
}
