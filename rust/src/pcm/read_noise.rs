//! Instantaneous 1/f read noise.
//!
//! Each read integrates device current for `t_read`; the accumulated
//! low-frequency noise grows with the time since programming:
//!
//!   σ_read(g, t) = g · Q_s(g) · √ln((t + t_read) / (2·t_read))
//!   Q_s(g)       = min(0.0088 / g_rel^0.65, q_s_max)
//!
//! (Joshi et al. 2020, eq. for 1/f noise; AIHWKIT `PCMLikeNoiseModel`.)

use super::PcmModel;
use crate::util::rng::Pcg64;

/// Relative 1/f amplitude for one conductance.
#[inline]
pub fn q_s(model: &PcmModel, g: f32) -> f32 {
    let g_rel = (g / model.g_max).max(1e-6);
    (0.0088 / g_rel.powf(0.65)).min(model.q_s_max)
}

/// Add read noise (in place) to drifted conductances at time `t`.
pub fn apply_read_noise(model: &PcmModel, g: &mut [f32], t_seconds: f64, rng: &mut Pcg64) {
    if model.noise_scale == 0.0 {
        return;
    }
    // Time factor is shared by every device in the read.
    let t = t_seconds.max(model.t_read);
    let time_factor = (((t + model.t_read) / (2.0 * model.t_read)).ln()).sqrt() as f32;
    for v in g.iter_mut() {
        let sigma = *v * q_s(model, *v) * time_factor * model.noise_scale;
        if sigma > 0.0 {
            *v = (*v + sigma * rng.normal_f32()).max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_s_larger_for_low_states_and_capped() {
        let m = PcmModel::default();
        assert!(q_s(&m, 0.5) > q_s(&m, 20.0));
        assert!(q_s(&m, 0.001) <= m.q_s_max);
    }

    #[test]
    fn noise_grows_with_time() {
        let m = PcmModel::default();
        let base = vec![20.0f32; 40_000];
        let sd_at = |t: f64, seed: u64| {
            let mut g = base.clone();
            apply_read_noise(&m, &mut g, t, &mut Pcg64::new(seed));
            let mean = g.iter().map(|x| *x as f64).sum::<f64>() / g.len() as f64;
            (g.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / g.len() as f64).sqrt()
        };
        let early = sd_at(1.0, 1);
        let late = sd_at(86_400.0 * 3650.0, 2);
        assert!(late > early, "late={late} early={early}");
    }

    #[test]
    fn conductances_stay_non_negative() {
        let m = PcmModel::default();
        let mut g = vec![0.05f32; 10_000];
        apply_read_noise(&m, &mut g, 86_400.0, &mut Pcg64::new(3));
        assert!(g.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn ideal_model_noop() {
        let m = PcmModel::ideal();
        let mut g = vec![5.0f32; 8];
        apply_read_noise(&m, &mut g, 1e6, &mut Pcg64::new(4));
        assert_eq!(g, vec![5.0f32; 8]);
    }
}
