//! GRPO training driver (Methods — RL: 500 steps, 16 samples/group,
//! lr 5e-6, warmup, weight decay 0.1 — scaled to proxy budgets).

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::manifest::Role;
use crate::config::run::TrainConfig;
use crate::data::gsm::GsmTask;
use crate::data::tokenizer::PAD;
use crate::model::params::ParamStore;
use crate::runtime::pack::{assemble_inputs, parse_step_outputs, DataArg};
use crate::runtime::{Engine, LoadedGraph};
use crate::util::rng::Pcg64;

use super::reward::{advantages, score, RewardBreakdown};
use super::sampling::{sample_group, SampleCfg};

pub struct GrpoTrainer {
    step_graph: Rc<LoadedGraph>,
    fwd_graph: Rc<LoadedGraph>,
    pub meta: ParamStore,
    pub train: ParamStore,
    m: ParamStore,
    v: ParamStore,
    pub cfg: TrainConfig,
    pub sample_cfg: SampleCfg,
    pub task: GsmTask,
    pub group: usize,
    pub seq: usize,
    pub step_idx: usize,
    /// Mean group reward per step (the RL learning curve).
    pub reward_curve: Vec<f64>,
    rng: Pcg64,
}

impl GrpoTrainer {
    pub fn new(
        engine: &Engine,
        variant: &str,
        meta: ParamStore,
        train: ParamStore,
        cfg: TrainConfig,
    ) -> Result<GrpoTrainer> {
        let step_graph = engine
            .load(&format!("{variant}/step_grpo_lora"))
            .context("loading grpo step graph")?;
        let fwd_graph = engine.load(&format!("{variant}/fwd_lm"))?;
        let v = engine.manifest.variant(variant)?;
        let group = engine.manifest.grpo_group;
        let seq = v.seq;
        meta.validate_against(&step_graph.spec, Role::Meta)?;
        train.validate_against(&step_graph.spec, Role::Train)?;
        let m = ParamStore::zeros_like_role(&step_graph.spec, Role::M);
        let vv = ParamStore::zeros_like_role(&step_graph.spec, Role::V);
        let rng = Pcg64::with_stream(cfg.seed, 0x6690);
        Ok(GrpoTrainer {
            step_graph,
            fwd_graph,
            meta,
            train,
            m,
            v: vv,
            cfg,
            sample_cfg: SampleCfg::default(),
            task: GsmTask::new(seq),
            group,
            seq,
            step_idx: 0,
            reward_curve: Vec::new(),
            rng,
        })
    }

    /// One GRPO step: sample a group for a fresh problem, reward, form
    /// advantages, policy-gradient update on the LoRA tree.
    pub fn step(&mut self) -> Result<f64> {
        let problem = self.task.problem(&mut self.rng);
        let hw = self.cfg.hw_vec();

        let completions = sample_group(
            &self.fwd_graph,
            &self.meta,
            &self.train,
            &problem.prompt,
            self.group,
            hw,
            &self.sample_cfg,
            &mut self.rng,
        )?;

        let rewards: Vec<f64> = completions
            .iter()
            .map(|c| score(c, problem.answer()).total())
            .collect();
        let adv = advantages(&rewards);
        let mean_reward = rewards.iter().sum::<f64>() / rewards.len() as f64;

        // pack [G, S] tokens + response mask
        let p = problem.prompt.len();
        let mut tokens = vec![PAD; self.group * self.seq];
        let mut mask = vec![0f32; self.group * self.seq];
        for (g, comp) in completions.iter().enumerate() {
            let row = &mut tokens[g * self.seq..(g + 1) * self.seq];
            row[..p].copy_from_slice(&problem.prompt);
            let take = comp.len().min(self.seq - p);
            row[p..p + take].copy_from_slice(&comp[..take]);
            for t in 0..take {
                mask[g * self.seq + p + t] = 1.0;
            }
        }

        let lr = self.cfg.lr_at(self.step_idx) as f32;
        let opt = [lr, self.cfg.weight_decay as f32, (self.step_idx + 1) as f32];
        let inputs = assemble_inputs(
            &self.step_graph.spec,
            &self.meta,
            &self.train,
            Some((&self.m, &self.v)),
            &[
                DataArg::I32(&tokens),
                DataArg::F32(&mask),
                DataArg::F32(&adv),
            ],
            self.rng.next_u64(),
            hw,
            Some(opt),
        )?;
        let outs = self.step_graph.run(&inputs)?;
        let (train, m, v, _loss) = parse_step_outputs(&self.step_graph.spec, &outs)?;
        self.train = train;
        self.m = m;
        self.v = v;
        self.step_idx += 1;
        self.reward_curve.push(mean_reward);
        Ok(mean_reward)
    }

    pub fn run(&mut self) -> Result<&[f64]> {
        let t0 = std::time::Instant::now();
        for s in 0..self.cfg.steps {
            let r = self.step()?;
            if self.cfg.log_every > 0 && (s + 1) % self.cfg.log_every == 0 {
                eprintln!(
                    "[grpo] step {}/{} mean reward {:.3} ({:.1} s/step)",
                    s + 1,
                    self.cfg.steps,
                    r,
                    t0.elapsed().as_secs_f64() / (s + 1) as f64
                );
            }
        }
        Ok(&self.reward_curve)
    }

    /// GSM accuracy: fraction of problems whose greedy completion has
    /// the exact right answer in the required format.
    pub fn evaluate(&mut self, n_problems: usize, hw: [f32; 5], seed: u64) -> Result<f64> {
        evaluate_gsm(
            &self.fwd_graph,
            &self.meta,
            &self.train,
            &self.task,
            n_problems,
            hw,
            seed,
        )
    }
}

/// Standalone GSM accuracy evaluation (Table V / Supp. Table X).
pub fn evaluate_gsm(
    fwd: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    task: &GsmTask,
    n_problems: usize,
    hw: [f32; 5],
    seed: u64,
) -> Result<f64> {
    let mut rng = Pcg64::new(seed);
    let mut correct = 0usize;
    // batched greedy: group problems into fwd-batch-sized sets by
    // sampling each problem's completion independently (greedy)
    for i in 0..n_problems {
        let p = task.problem(&mut rng);
        let comp = super::sampling::greedy(fwd, meta, train, &p.prompt, 14, hw, seed ^ (i as u64) << 3)?;
        let r: RewardBreakdown = score(&comp, p.answer());
        if r.answer_exact > 0.0 {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / n_problems as f64)
}
