//! Reinforcement learning via Group Relative Policy Optimization
//! (Methods — Instruction Tuning and Reinforcement Learning).
//!
//! The policy is the analog decoder (meta weights on simulated AIMC,
//! LoRA on the DPUs); only the LoRA tree is updated. For each prompt
//! the coordinator samples a 16-completion group ([`sampling`]), scores
//! it with the 4-component reward capped at 9.5 ([`reward`]),
//! normalises advantages within the group, and executes the
//! AOT-compiled `step_grpo_lora` graph ([`grpo`]).

pub mod grpo;
pub mod reward;
pub mod sampling;
