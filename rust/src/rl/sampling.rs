//! Autoregressive sampling through the AOT-compiled decoder forward
//! graph.
//!
//! The compiled `fwd_lm` graph scores a full [B, S] buffer per call; the
//! sampler iterates positions, re-running the graph on the growing
//! prefix (no KV cache — at proxy scale a full forward is a few
//! milliseconds, and the compiled artifact stays single). Temperature +
//! top-k sampling; generation stops at `</SOLUTION>`/EOS or after
//! `max_new` tokens.

use anyhow::Result;

use crate::data::tokenizer::{EOS, ESOL, PAD};
use crate::eval::drift_eval::{fwd_batch_shape, lm_logits};
use crate::model::params::ParamStore;
use crate::runtime::LoadedGraph;
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f64,
    pub top_k: usize,
    pub max_new: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg {
            temperature: 0.8,
            top_k: 12,
            max_new: 14,
        }
    }
}

/// Greedy when `temperature == 0`.
pub fn pick_token(logits: &[f32], cfg: &SampleCfg, rng: &mut Pcg64) -> i32 {
    if cfg.temperature <= 0.0 {
        return crate::eval::metrics::argmax(logits) as i32;
    }
    // top-k + temperature softmax
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    let k = cfg.top_k.min(logits.len());
    idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
    idx.truncate(k);
    let mx = logits[idx[0]] as f64;
    let weights: Vec<f32> = idx
        .iter()
        .map(|&i| (((logits[i] as f64 - mx) / cfg.temperature).exp()) as f32)
        .collect();
    idx[rng.categorical(&weights)] as i32
}

/// Sample `n` completions of the same prompt. Returns completions
/// (tokens after the prompt, stop token excluded).
pub fn sample_group(
    graph: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    prompt: &[i32],
    n: usize,
    hw: [f32; 5],
    cfg: &SampleCfg,
    rng: &mut Pcg64,
) -> Result<Vec<Vec<i32>>> {
    let (b, s) = fwd_batch_shape(graph);
    let vocab = graph.spec.outputs[0].shape[2];
    let p = prompt.len().min(s - 1);
    let mut completions: Vec<Vec<i32>> = Vec::with_capacity(n);

    let mut done = 0;
    while done < n {
        let take = (n - done).min(b);
        // batch buffer starts as the prompt replicated
        let mut buf = vec![PAD; b * s];
        for row in 0..take {
            buf[row * s..row * s + p].copy_from_slice(&prompt[..p]);
        }
        let mut len = vec![p; take];
        let mut alive = vec![true; take];

        let max_new = cfg.max_new.min(s - p);
        for step in 0..max_new {
            if !alive.iter().any(|&a| a) {
                break;
            }
            let logits = lm_logits(graph, meta, train, &buf, hw, rng.next_u64())?;
            for row in 0..take {
                if !alive[row] {
                    continue;
                }
                let pos = len[row] - 1; // next-token logits at last filled pos
                let off = (row * s + pos) * vocab;
                let tok = pick_token(&logits[off..off + vocab], cfg, rng);
                buf[row * s + len[row]] = tok;
                len[row] += 1;
                if tok == ESOL || tok == EOS || len[row] >= s {
                    alive[row] = false;
                }
            }
            let _ = step;
        }
        for row in 0..take {
            completions.push(buf[row * s + p..row * s + len[row]].to_vec());
        }
        done += take;
    }
    Ok(completions)
}

/// Greedy-decode one completion (evaluation path).
pub fn greedy(
    graph: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    prompt: &[i32],
    max_new: usize,
    hw: [f32; 5],
    seed: u64,
) -> Result<Vec<i32>> {
    let cfg = SampleCfg {
        temperature: 0.0,
        top_k: 1,
        max_new,
    };
    let mut rng = Pcg64::new(seed);
    Ok(sample_group(graph, meta, train, prompt, 1, hw, &cfg, &mut rng)?.remove(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_pick_is_argmax() {
        let cfg = SampleCfg {
            temperature: 0.0,
            ..Default::default()
        };
        let mut rng = Pcg64::new(1);
        assert_eq!(pick_token(&[0.1, 0.9, 0.3], &cfg, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let cfg = SampleCfg {
            temperature: 1.0,
            top_k: 2,
            max_new: 4,
        };
        let mut rng = Pcg64::new(2);
        let logits = vec![5.0f32, 4.9, -10.0, -10.0];
        for _ in 0..200 {
            let t = pick_token(&logits, &cfg, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn high_temperature_spreads_low_sharpens() {
        let mut hits_hot = [0usize; 3];
        let mut hits_cold = [0usize; 3];
        let logits = vec![2.0f32, 1.0, 0.0];
        let mut rng = Pcg64::new(3);
        let hot = SampleCfg {
            temperature: 5.0,
            top_k: 3,
            max_new: 1,
        };
        let cold = SampleCfg {
            temperature: 0.1,
            top_k: 3,
            max_new: 1,
        };
        for _ in 0..500 {
            hits_hot[pick_token(&logits, &hot, &mut rng) as usize] += 1;
            hits_cold[pick_token(&logits, &cold, &mut rng) as usize] += 1;
        }
        assert!(hits_cold[0] > 480, "cold should concentrate: {hits_cold:?}");
        assert!(hits_hot[2] > 50, "hot should spread: {hits_hot:?}");
    }
}
