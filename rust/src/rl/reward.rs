//! The 4-component GSM reward (max 9.5, Methods — RL).
//!
//! Completions must follow the paper's output grammar
//! `<start_working_out> … <end_working_out> <SOLUTION> n </SOLUTION>`:
//!
//! 1. working-out tags present and ordered            → 1.0
//! 2. solution tags present and ordered               → 1.5
//! 3. exact final answer inside the solution tags     → 5.0
//! 4. digit-level partial credit on the answer        → up to 2.0
//!
//! Component 4 keeps early training informative (the paper lowers RL
//! noise to 3 % for the same reason — near-random groups give GRPO no
//! signal).

use crate::data::tokenizer::{decode_number, EOW, ESOL, SOL, SOW};

pub const MAX_REWARD: f64 = 9.5;

#[derive(Clone, Copy, Debug, Default)]
pub struct RewardBreakdown {
    pub format_working: f64,
    pub format_solution: f64,
    pub answer_exact: f64,
    pub answer_partial: f64,
}

impl RewardBreakdown {
    pub fn total(&self) -> f64 {
        self.format_working + self.format_solution + self.answer_exact + self.answer_partial
    }
}

/// Extract the number between the solution tags, if well-formed.
pub fn extract_answer(completion: &[i32]) -> Option<u32> {
    let sol = completion.iter().position(|&t| t == SOL)?;
    let esol = completion.iter().position(|&t| t == ESOL)?;
    if esol <= sol {
        return None;
    }
    let (val, len) = decode_number(completion, sol + 1)?;
    // the digit run must span exactly the tag interior
    if sol + 1 + len == esol {
        Some(val)
    } else {
        None
    }
}

pub fn score(completion: &[i32], expected: u32) -> RewardBreakdown {
    let mut r = RewardBreakdown::default();

    let sow = completion.iter().position(|&t| t == SOW);
    let eow = completion.iter().position(|&t| t == EOW);
    if let (Some(s), Some(e)) = (sow, eow) {
        if s < e {
            r.format_working = 1.0;
        }
    }

    let sol = completion.iter().position(|&t| t == SOL);
    let esol = completion.iter().position(|&t| t == ESOL);
    if let (Some(s), Some(e)) = (sol, esol) {
        if s < e {
            r.format_solution = 1.5;
        }
    }

    if let Some(ans) = extract_answer(completion) {
        if ans == expected {
            r.answer_exact = 5.0;
            r.answer_partial = 2.0;
        } else {
            // digit-level overlap: right-aligned digit matches
            let (mut a, mut b) = (ans, expected);
            let mut matches = 0usize;
            let mut digits = 0usize;
            while a > 0 || b > 0 || digits == 0 {
                if a % 10 == b % 10 {
                    matches += 1;
                }
                digits += 1;
                a /= 10;
                b /= 10;
            }
            r.answer_partial = 2.0 * matches as f64 / digits as f64;
        }
    }
    r
}

/// Group-relative advantages: (r − mean)/(std + ε) over the group —
/// GRPO's critic-free baseline.
pub fn advantages(rewards: &[f64]) -> Vec<f32> {
    let n = rewards.len() as f64;
    let mean = rewards.iter().sum::<f64>() / n;
    let var = rewards.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt() + 1e-6;
    rewards.iter().map(|r| ((r - mean) / std) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gsm::GsmProblem;

    #[test]
    fn ideal_completion_hits_max() {
        let p = GsmProblem {
            a: 23,
            b: 19,
            prompt: vec![],
        };
        let r = score(&p.ideal_completion(), p.answer());
        assert_eq!(r.total(), MAX_REWARD);
    }

    #[test]
    fn garbage_scores_zero() {
        let r = score(&[40, 41, 42], 7);
        assert_eq!(r.total(), 0.0);
    }

    #[test]
    fn format_only_partial_credit() {
        use crate::data::tokenizer::digit;
        // tags fine, wrong answer 43 vs 42: last digit differs, first matches
        let c = vec![SOW, EOW, SOL, digit(4), digit(3), ESOL];
        let r = score(&c, 42);
        assert_eq!(r.format_working, 1.0);
        assert_eq!(r.format_solution, 1.5);
        assert_eq!(r.answer_exact, 0.0);
        assert!((r.answer_partial - 1.0).abs() < 1e-9); // 1 of 2 digits
    }

    #[test]
    fn out_of_order_tags_rejected() {
        use crate::data::tokenizer::digit;
        let c = vec![EOW, SOW, ESOL, digit(1), SOL];
        let r = score(&c, 1);
        assert_eq!(r.total(), 0.0);
    }

    #[test]
    fn extract_rejects_junk_inside_tags() {
        use crate::data::tokenizer::digit;
        assert_eq!(extract_answer(&[SOL, digit(4), digit(2), ESOL]), Some(42));
        assert_eq!(extract_answer(&[SOL, digit(4), SOW, ESOL]), None);
        assert_eq!(extract_answer(&[SOL, ESOL]), None);
    }

    #[test]
    fn advantages_are_zero_mean_unit_scale() {
        let adv = advantages(&[9.5, 0.0, 0.0, 0.0]);
        let mean: f32 = adv.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!(adv[0] > 1.0 && adv[1] < 0.0);
    }

    #[test]
    fn uniform_rewards_give_zero_advantage() {
        let adv = advantages(&[3.0; 8]);
        assert!(adv.iter().all(|a| a.abs() < 1e-3));
    }
}
