//! Cross-worker conformance suite for pool-level refresh coordination
//! (`serve::coord`), on the shared `tests/common/refresh_sim.rs`
//! harness — ONE `VirtualClock` under a ≥4-worker pool with 4 tasks
//! sharing a drift tolerance, zero real-time sleeps. The geometry is
//! scale-free ([`refresh_sim::CoordGeom`]): every duration derives from
//! the modeled single-request latency, so the pins hold on any
//! hardware model.
//!
//! Pinned:
//!
//! * **Hold concurrency.** With a coordinator at
//!   `max_concurrent_holds = 1`, no instant ever has more than one
//!   shard deferring a batch for a pending hot-swap — while the
//!   uncoordinated baseline (same tolerance, same pacing) provably
//!   stalls ALL four shards at once (the correlated-stall failure the
//!   coordinator exists to fix).
//! * **Freshness.** Staggering only ever moves triggers *earlier*:
//!   every task still swaps within its tolerance slack — at or before
//!   `modeled_due + one check interval + one refit budget` — while the
//!   baseline's serialized refits provably blow past that bound.
//! * **Adaptive window.** After a few refresh cycles each task's
//!   coordinator-assigned coupling window converges to within 2× of
//!   the true observed swap → first-serve gap, while the fixed-window
//!   baseline provably over-holds (its window exceeds twice the true
//!   gap the same pacing produces) AND under-serves (serialized refits
//!   inflate its swap gaps).
//! * **Stagger assignment** (property tests, `Gen::duration_in`):
//!   deterministic, permutation-invariant in task order, total-order
//!   preserving on trigger times, never later than the modeled
//!   trigger, never more than `slack` earlier.

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use ahwa_lora::serve::{stagger_assign, Clock, StaggerEntry, VirtualClock};
use ahwa_lora::util::proptest::check;
use refresh_sim::{CoordGeom, SimPool};

const TASKS: [&str; 4] = ["t0", "t1", "t2", "t3"];
/// 3 trigger cycles (`trigger_in` = 600 arrivals).
const ROUNDS: usize = 1800;

fn run(pool: &mut SimPool, geom: &CoordGeom, rounds: usize) {
    pool.run_rounds(rounds, geom.ia);
    pool.flush(geom.ia);
}

#[test]
fn coordinator_bounds_concurrent_holds_and_keeps_every_swap_fresh() {
    let geom = CoordGeom::derive();
    let mut pool = geom.pool(4, &TASKS, true, 1);
    run(&mut pool, &geom, ROUNDS);

    assert_eq!(pool.served(), ROUNDS * TASKS.len(), "every request served");
    assert!(pool.holds > 0, "shards did defer for pending swaps");
    assert!(
        pool.swaps.len() >= 2 * TASKS.len(),
        "≥2 refresh cycles per task actually ran ({} swaps)",
        pool.swaps.len()
    );

    // pin 1: never more than max_concurrent_holds shards holding —
    // observed at every scheduling decision on the shared clock
    assert!(
        pool.max_holding <= 1,
        "at most one shard may hold at any instant, saw {}",
        pool.max_holding
    );
    assert!(
        pool.metrics.concurrent_holds_peak.load(Ordering::Relaxed) <= 1,
        "the metric agrees with the observed peak"
    );

    // pin 2: staggering never sacrifices freshness — every swap lands
    // within the slack window, at or before modeled_due + margin
    let slack = pool.coordinator.as_ref().unwrap().config().slack;
    for rec in &pool.swaps {
        assert!(
            rec.at <= rec.modeled_due + geom.margin(1),
            "task {} swapped late: {:?} past its modeled crossing",
            rec.task,
            rec.at.saturating_duration_since(rec.modeled_due),
        );
        assert!(
            rec.at + slack >= rec.modeled_due,
            "task {} swapped more than the slack early",
            rec.task,
        );
    }

    // the stagger actually engaged (not a vacuous pass): triggers that
    // coincided were re-phased
    assert!(
        pool.metrics.stagger_shift_ns.load(Ordering::Relaxed) > 0,
        "colliding triggers must have been re-phased"
    );

    // pin 3: each task's adaptive window converged to within 2× of its
    // true observed swap gap — which the fixed window provably cannot
    // match: it exceeds twice that gap (over-holds)
    for task in TASKS {
        let gap = pool.mean_gap(task).expect("gaps observed");
        assert!(gap > Duration::ZERO, "the swap -> serve handoff takes real time");
        let window = pool
            .handle
            .adaptive_window(task)
            .expect("adaptive window assigned after refreshes");
        assert!(
            window <= gap * 2 && window * 2 >= gap,
            "task {task}: adaptive window {window:?} not within 2x of true gap {gap:?}"
        );
        assert!(
            geom.fixed_window > gap * 2,
            "the fixed window {:?} must provably over-hold against the true gap {gap:?}",
            geom.fixed_window
        );
        // ...and the adaptive hold tracks the measured refit budget
        let hold = pool
            .handle
            .adaptive_hold(task)
            .expect("adaptive hold derived from the refit budget");
        assert!(
            hold >= geom.refit,
            "task {task}: hold {hold:?} under the measured refit budget {:?}",
            geom.refit
        );
    }
}

#[test]
fn uncoordinated_baseline_exhibits_correlated_stalls_and_stale_holds() {
    let geom = CoordGeom::derive();
    let mut pool = geom.pool(4, &TASKS, false, 1);
    run(&mut pool, &geom, ROUNDS);
    assert_eq!(pool.served(), ROUNDS * TASKS.len(), "every request still served");

    // the correlated-stall failure is REAL: all four shards sat in a
    // hold window at the same instant at least once
    assert_eq!(
        pool.max_holding,
        TASKS.len(),
        "tasks sharing a tolerance must stall every shard at once"
    );

    // and the serialized refits blow the freshness bound the
    // coordinated pool meets for every swap
    let late = pool
        .swaps
        .iter()
        .filter(|r| r.at > r.modeled_due + geom.margin(1))
        .count();
    assert!(
        late > 0,
        "back-to-back refits must push some swap past one check interval + one refit budget"
    );

    // the under-hold side of the fixed policy: serialized refits
    // inflate the first-serialized task's swap gap far past the one
    // arrival the coordinated pool sustains
    let worst_gap = TASKS
        .iter()
        .filter_map(|t| pool.mean_gap(t))
        .max()
        .expect("gaps observed");
    assert!(
        worst_gap > geom.ia * 2,
        "serialized refits must inflate some task's swap gap well past one arrival ({worst_gap:?})"
    );
    for task in TASKS {
        assert_eq!(
            pool.handle.adaptive_window(task),
            None,
            "no coordinator, no adaptive state"
        );
        assert_eq!(pool.handle.staggered_at(task), None);
    }
}

#[test]
fn stagger_assignment_is_deterministic_permutation_invariant_order_preserving() {
    let clock = VirtualClock::new();
    let base = clock.now() + Duration::from_secs(3600);

    check("stagger-assign-props", 64, |g| {
        let n = g.usize_in(1, 12);
        let entries: Vec<StaggerEntry> = (0..n)
            .map(|i| StaggerEntry {
                task: format!("task{i}"),
                trigger: base + g.duration_in(Duration::ZERO, Duration::from_millis(50)),
                span: g.duration_in(Duration::from_micros(10), Duration::from_millis(5)),
            })
            .collect();
        let k = g.usize_in(1, 4);
        let slack = g.duration_in(Duration::from_millis(1), Duration::from_millis(200));

        let a = stagger_assign(&entries, k, slack);
        assert_eq!(a.len(), entries.len(), "every entry is assigned");

        // deterministic: same input, same output
        assert_eq!(a, stagger_assign(&entries, k, slack));

        // permutation-invariant: a shuffled input yields the same
        // task → instant mapping
        let mut shuffled = entries.clone();
        shuffled.reverse();
        shuffled.rotate_left(g.usize_in(0, n - 1));
        let to_map = |v: &[(String, Instant)]| -> BTreeMap<String, Instant> {
            v.iter().cloned().collect()
        };
        let m = to_map(&a);
        assert_eq!(m, to_map(&stagger_assign(&shuffled, k, slack)));

        // never later than the modeled trigger, never more than slack
        // earlier
        for e in &entries {
            let at = m[&e.task];
            assert!(at <= e.trigger, "stagger may never delay a trigger");
            assert!(
                e.trigger - at <= slack,
                "shift {:?} escaped the slack {:?}",
                e.trigger - at,
                slack
            );
        }

        // total-order preserving on (trigger, task)
        let mut sorted = entries.clone();
        sorted.sort_by(|x, y| x.trigger.cmp(&y.trigger).then_with(|| x.task.cmp(&y.task)));
        for w in sorted.windows(2) {
            assert!(
                m[&w[0].task] <= m[&w[1].task],
                "assignment must preserve the trigger total order"
            );
        }

        // with generous slack the concurrency bound holds exactly: at
        // every assigned start, at most k spans cover it
        let roomy = stagger_assign(&entries, k, Duration::from_secs(10));
        let rm = to_map(&roomy);
        for (_, at) in &roomy {
            let covering = entries
                .iter()
                .filter(|e| {
                    let s = rm[&e.task];
                    s <= *at && *at < s + e.span
                })
                .count();
            assert!(covering <= k, "{covering} spans overlap at one instant (k={k})");
        }
    });
}

/// Multi-worker stress variant: 8 workers × 16 tasks sharing one
/// tolerance at `max_concurrent_holds = 2`, a longer stream, same pins.
/// Still zero real sleeps — but heavy, so it runs in the release lane
/// only (`ci.sh --stage test-release`), like `refresh_stress.rs`.
#[test]
fn coord_stress_many_tasks_many_workers() {
    if cfg!(debug_assertions) {
        eprintln!("skipping coord stress: debug build (the --release CI lane runs it)");
        return;
    }
    let mut geom = CoordGeom::derive();
    // lighter refits, two cycles over a longer stream, and enough slack
    // for 8 stagger slots of first-cycle (fallback) spacing
    geom.refit = geom.ia * 5;
    geom.trigger_in = geom.ia * 1200;
    geom.slack = geom.ia * 800;
    let tasks: Vec<String> = (0..16).map(|i| format!("task{i:02}")).collect();
    let task_refs: Vec<&str> = tasks.iter().map(|s| s.as_str()).collect();
    let mut pool = geom.pool(8, &task_refs, true, 2);
    let rounds = 3000;
    run(&mut pool, &geom, rounds);

    assert_eq!(pool.served(), rounds * tasks.len(), "no request lost");
    assert!(
        pool.swaps.len() >= tasks.len(),
        "at least one full refresh cycle ran ({} swaps)",
        pool.swaps.len()
    );
    assert!(
        pool.max_holding <= 2,
        "hold concurrency bound (2) violated: {}",
        pool.max_holding
    );
    // at k=2 the two tasks sharing a stagger slot refresh back to back
    // within one tick, so the freshness bound covers a pair of refits
    // (plus one tick interval and a cushion)
    for rec in &pool.swaps {
        assert!(
            rec.at <= rec.modeled_due + geom.margin(3),
            "task {} swapped late under stress: {:?} past its modeled crossing",
            rec.task,
            rec.at.saturating_duration_since(rec.modeled_due),
        );
    }
}
