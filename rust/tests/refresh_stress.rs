//! Concurrency stress tests for the refresh ↔ scheduler ↔ registry
//! triangle, on the REAL clock: reader/client threads race a storm of
//! forced refresh evaluations and the suite asserts the bookkeeping
//! invariants hold exactly — adapter-swap count == version bumps
//! observed, no ticket lost, `refresh_errors == 0`, and no torn
//! (adapter, version) pair is ever visible.
//!
//! These tests run only in the `--release` lane (`ci.sh --stage
//! test-release`); the debug lane skips them so `cargo test -q` stays
//! fast. The pool test additionally needs built artifacts and
//! self-skips without them, like the other PJRT-backed suites.
//!
//! The runner spin-up (analytic decay over a tagged single-tensor
//! adapter) comes from the shared `tests/common/refresh_sim.rs`
//! harness, same as the conformance suites.

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest};
use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::model::checkpoint;
use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    DecayModel, FnRefitter, Metrics, Refit, RefreshConfig, RefreshCoupling, SchedConfig, Server,
};
use ahwa_lora::util::rng::Pcg64;
use refresh_sim::{adapter, analytic_runner};

/// Skip in debug builds: these tests spin real threads against the
/// real clock and belong in the release lane only.
fn release_only() -> bool {
    if cfg!(debug_assertions) {
        eprintln!("skipping stress test: debug build (the --release CI lane runs it)");
        return false;
    }
    true
}

/// Hermetic storm: concurrent `tick` callers (the `refresh_tick_now`
/// path is exactly a locked tick on the pool clock) race snapshot
/// readers while refreshes fire every ~2ms of real time.
#[test]
fn refresh_tick_storm_keeps_registry_and_metrics_consistent() {
    if !release_only() {
        return;
    }
    let registry = SharedRegistry::new();
    registry.deploy("task", adapter(1.0));

    // the refitted adapter's payload encodes the version the CAS will
    // assign (current + 1): readers can detect torn pairs exactly
    let refitter = Arc::new(FnRefitter(
        |_: &str, current: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
            Ok(Refit {
                params: adapter(current.tensors[0].data[0] + 1.0),
                steps: budget,
            })
        },
    ));
    let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
    let metrics = Arc::new(Metrics::default());
    // a refresh becomes due every ~2ms of real clock
    let mut runner = analytic_runner(&registry, refitter, 0.05, age / 2e-3, metrics.clone());
    runner.track_deployed(Instant::now());
    let runner = Arc::new(Mutex::new(runner));

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        // the tick storm: 4 threads forcing evaluations concurrently
        let mut storms = Vec::new();
        for _ in 0..4 {
            let (runner, stop) = (runner.clone(), stop.clone());
            storms.push(scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    runner.lock().unwrap().tick(Instant::now());
                    std::thread::sleep(Duration::from_micros(200));
                }
            }));
        }
        // readers playing the request path: never a torn pair, never a
        // version regression
        let mut readers = Vec::new();
        for _ in 0..3 {
            let (registry, stop) = (registry.clone(), stop.clone());
            readers.push(scope.spawn(move || {
                let mut last = 0u64;
                let mut reads = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let (params, version) = registry.snapshot("task").expect("deployed");
                    assert!(version >= last, "version regressed: {version} < {last}");
                    assert_eq!(
                        params.tensors[0].data[0], version as f32,
                        "torn (adapter, version) pair"
                    );
                    last = version;
                    reads += 1;
                    std::thread::yield_now();
                }
                reads
            }));
        }
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, Ordering::Release);
        for s in storms {
            s.join().unwrap();
        }
        for r in readers {
            let reads = r.join().unwrap();
            assert!(reads > 0, "reader actually raced the storm");
        }
    });

    let runner = runner.lock().unwrap();
    let refreshes = metrics.refreshes.load(Ordering::Relaxed);
    assert!(refreshes >= 10, "the storm drove many refresh cycles: {refreshes}");
    assert_eq!(metrics.refresh_errors.load(Ordering::Relaxed), 0);
    // every version bump is a refresh, none lost, none double-counted
    assert_eq!(
        registry.version("task").unwrap(),
        1 + refreshes,
        "version bumps observed == adapter refreshes performed"
    );
    assert_eq!(runner.events().len() as u64, refreshes);
    // the event log records each swap's version exactly once, in order
    for (i, ev) in runner.events().iter().enumerate() {
        assert_eq!(ev.version, i as u64 + 2);
    }
}

/// Full-pool storm (needs artifacts): N client threads submit through
/// the coupled scheduler while one thread hammers `refresh_tick_now`;
/// every ticket must resolve Ok, the refresh loop must stay error-free,
/// and the pool's adapter-swap count must equal the distinct adapter
/// versions the clients observed.
#[test]
fn pool_survives_client_threads_and_refresh_tick_storm() {
    if !release_only() {
        return;
    }
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(default_artifacts_dir()).unwrap();
    let v = manifest.variant("tiny").unwrap().clone();
    let meta = checkpoint::load(manifest.init_path("tiny.meta")).unwrap();
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train")).unwrap();
    let registry = SharedRegistry::new();
    registry.deploy("SST-2", adapter.clone());

    let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
    let refit_params = adapter.clone();
    let rcfg = RefreshConfig::new(
        DecayModel::analytic(PcmModel::default()),
        Arc::new(FnRefitter(
            move |_: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
                Ok(Refit {
                    params: refit_params.clone(),
                    steps: budget,
                })
            },
        )),
    )
    .tolerance(0.05)
    .time_scale(age / 0.02) // a refresh becomes due every ~20ms
    .check_every(Duration::from_millis(5));

    let server = Server::builder("tiny")
        .manifest(manifest)
        .workers(1)
        .queue_depth(64)
        .max_batch(4)
        .max_wait(Duration::from_millis(2))
        .scheduler(
            SchedConfig::for_layer(v.d_model, v.d_model, v.rank)
                .coupling(RefreshCoupling::default()),
        )
        .refresh(rcfg)
        .build(meta, registry)
        .unwrap();
    let client = server.client();

    const THREADS: usize = 4;
    const PER_THREAD: usize = 40;
    let stop = Arc::new(AtomicBool::new(false));
    let mut observed: Vec<u64> = Vec::new();
    std::thread::scope(|scope| {
        let storm = {
            let (server_ref, stop) = (&server, stop.clone());
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    server_ref.refresh_tick_now();
                    std::thread::sleep(Duration::from_micros(500));
                }
            })
        };
        let clients: Vec<_> = (0..THREADS)
            .map(|t| {
                let client = client.clone();
                let gen = GlueGen::new(GlueTask::Sst2, v.vocab, v.seq);
                scope.spawn(move || {
                    let mut rng = Pcg64::new(100 + t as u64);
                    let mut versions = Vec::with_capacity(PER_THREAD);
                    for _ in 0..PER_THREAD {
                        let (tokens, _, _) = gen.example(&mut rng);
                        let r = client
                            .submit_with_retry("SST-2", &tokens, Duration::from_secs(30))
                            .expect("admitted")
                            .wait()
                            .expect("every ticket resolves Ok under the storm");
                        assert!(r.logits.iter().all(|x| x.is_finite()));
                        versions.push(r.adapter_version);
                    }
                    versions
                })
            })
            .collect();
        for c in clients {
            observed.extend(c.join().unwrap());
        }
        stop.store(true, Ordering::Release);
        storm.join().unwrap();
    });

    // no ticket lost: every submitted request produced a response
    assert_eq!(observed.len(), THREADS * PER_THREAD);
    let agg = server.metrics();
    assert_eq!(agg.refresh_errors, 0, "refresh loop stayed error-free");
    assert_eq!(agg.errors, 0, "no request failed");
    assert_eq!(agg.served, (THREADS * PER_THREAD) as u64);
    // adapter-swap accounting: with one worker and one task the served
    // version sequence is monotone, so the worker's swap count must
    // equal the number of distinct versions the clients observed
    let mut distinct: Vec<u64> = observed.clone();
    distinct.sort_unstable();
    distinct.dedup();
    assert_eq!(
        agg.adapter_swaps,
        distinct.len() as u64,
        "adapter-swap count == version bumps observed by clients"
    );
    assert_eq!(
        server.refresh_events().len() as u64,
        agg.refreshes,
        "event log and refresh counter agree"
    );
    assert!(agg.refreshes >= 1, "the storm drove at least one refresh");
    server.shutdown().unwrap();
}
