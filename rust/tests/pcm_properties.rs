//! Property tests for the statistical PCM device model, via the
//! in-tree `util::proptest` mini-framework (hermetic: no artifacts).
//!
//! Pinned invariants:
//! * drift is a pure decay for t > 0 — every per-device factor lies in
//!   (0, 1],
//! * sampled drift exponents never escape `nu_clip`, whatever the
//!   conductance state,
//! * `noise_scale = 0` makes programming and read noise *exactly* the
//!   identity (the "digital baseline" contract every experiment's
//!   clean column relies on).

use ahwa_lora::pcm::{drift, programming, read_noise, PcmModel};
use ahwa_lora::util::proptest::check;
use ahwa_lora::util::rng::Pcg64;

#[test]
fn drift_factors_lie_in_unit_interval_for_positive_time() {
    check("drift-factor-in-(0,1]", 64, |g| {
        let model = PcmModel::default();
        let len = g.usize_in(1, 64);
        let g_prog = g.vec_f32(len, 0.01, model.g_max);
        let mut rng = Pcg64::new(g.seed ^ 0xd21f7);
        let nu = drift::sample_nu(&model, &g_prog, &mut rng);
        let t = g.f64_in(1e-3, 3.2e8); // sub-ms .. ten years
        let mut out = vec![0f32; len];
        drift::apply_drift(&model, &g_prog, &nu, t, &mut out);
        for (o, gp) in out.iter().zip(&g_prog) {
            let factor = o / gp;
            assert!(
                factor > 0.0 && factor <= 1.0,
                "drift factor {factor} escaped (0, 1] at t={t}s (g={gp})"
            );
        }
    });
}

#[test]
fn sampled_drift_exponents_respect_nu_clip() {
    check("sample-nu-within-clip", 64, |g| {
        let model = PcmModel::default();
        let len = g.usize_in(1, 256);
        // include zero states and physical overshoot above g_max
        let g_prog = g.vec_f32(len, 0.0, 1.2 * model.g_max);
        let mut rng = Pcg64::new(g.seed ^ 0x5eed5);
        let nu = drift::sample_nu(&model, &g_prog, &mut rng);
        assert_eq!(nu.len(), len);
        for (v, gp) in nu.iter().zip(&g_prog) {
            assert!(
                (model.nu_clip.0..=model.nu_clip.1).contains(v),
                "nu {v} outside clip {:?} for g={gp}",
                model.nu_clip
            );
        }
    });
}

#[test]
fn zero_noise_scale_makes_programming_and_read_noise_identity() {
    check("ideal-model-identity", 64, |g| {
        let model = PcmModel::ideal();
        assert_eq!(model.noise_scale, 0.0);
        let len = g.usize_in(1, 128);
        let mut buf = g.vec_f32(len, 0.0, model.g_max);
        let orig = buf.clone();
        let mut rng = Pcg64::new(g.seed);
        programming::apply_programming_noise(&model, &mut buf, &mut rng);
        assert_eq!(buf, orig, "programming noise must be exactly identity");
        let t = g.f64_in(0.0, 3.2e8);
        read_noise::apply_read_noise(&model, &mut buf, t, &mut rng);
        assert_eq!(buf, orig, "read noise must be exactly identity");
    });
}
