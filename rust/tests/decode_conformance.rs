//! Conformance suite for the continuous-batching decode subsystem
//! (`serve::decode` + the pool worker's decode pass), on the shared
//! `SimPool`/`SimDecode` harness — every scenario runs on the
//! `VirtualClock`, zero real sleeps:
//!
//! * continuous join strictly beats static run-to-completion batching
//!   on modeled step-batch occupancy over the SAME arrival trace (and
//!   produces bit-identical completions);
//! * a due refresh hot-swap lands BETWEEN steps of in-flight sequences
//!   — a sequence starts on version v and finishes on v+1, with zero
//!   steps served against a stale-past-trigger snapshot and the
//!   crossing counted in `mid_seq_swaps`;
//! * the step gate defers the boundary (bounded hold) when the swap has
//!   not landed yet, and releases the moment it does;
//! * retiring a row at its stop token never blocks joiners: the freed
//!   slot is refilled at the very next step boundary;
//! * decode composes with `serve::coord` staggering — two lanes sharing
//!   one drift tolerance cross their (staggered) swaps mid-sequence
//!   with zero stale steps;
//! * release lane only: an 8-worker long-sequence decode storm over the
//!   same invariants.

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{CoordConfig, Metrics, VirtualClock};
use refresh_sim::{
    adapter, decode_refresh, decode_trace, drive_decode, DecodeArrival, DecodeOutcome, SimDecode,
    DECODE_CONTENT, DECODE_STOP,
};

/// Skip in debug builds: the storm belongs in the release CI lane (same
/// gate as `tests/refresh_stress.rs`).
fn release_only() -> bool {
    if cfg!(debug_assertions) {
        eprintln!("skipping stress test: debug build (the --release CI lane runs it)");
        return false;
    }
    true
}

/// Registry + clock + metrics for refresh-free decode scenarios.
fn decode_only(task: &str) -> (Arc<VirtualClock>, SharedRegistry, Arc<Metrics>) {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    registry.deploy(task, adapter(1.0));
    (clock, registry, Arc::new(Metrics::default()))
}

/// The expected completion of a `gen_len` request under the synthetic
/// model: `gen_len` content tokens, then the stop token.
fn expected_tokens(gen_len: usize) -> Vec<i32> {
    let mut t = vec![DECODE_CONTENT; gen_len];
    t.push(DECODE_STOP);
    t
}

// ---------------------------------------------------------------------------
// Continuous vs static occupancy
// ---------------------------------------------------------------------------

#[test]
fn continuous_join_beats_static_batching_on_modeled_occupancy() {
    // one burst of 24 requests with mixed generation lengths: the
    // static baseline must run each 4-row batch to its LONGEST member
    // while retired rows sit idle; continuous refills them immediately
    let lens = [2usize, 9, 4, 7, 3, 8, 5, 6];
    let trace = decode_trace(24, Duration::ZERO, &lens);

    let run = |continuous: bool| {
        let (clock, registry, metrics) = decode_only("task");
        let start = clock.now();
        let mut sim = SimDecode::new(clock, metrics, 4, 32, continuous);
        drive_decode(&mut sim, &registry, None, None, "task", &trace);
        (sim, start)
    };
    let (cont, cont_start) = run(true);
    let (stat, stat_start) = run(false);

    // identical work completed, token for token
    for sim in [&cont, &stat] {
        assert_eq!(sim.finished.len(), trace.len());
        for g in &sim.finished {
            assert_eq!(
                g.tokens,
                expected_tokens(trace[g.id as usize].gen_len),
                "generation {} must decode its full budget then stop",
                g.id
            );
        }
    }

    // the tentpole claim: strictly higher modeled step-batch occupancy
    // on the same arrival trace
    assert!(
        cont.occupancy() > stat.occupancy(),
        "continuous occupancy {:.3} must beat static {:.3}",
        cont.occupancy(),
        stat.occupancy()
    );
    // same tokens in fewer, fuller steps → a strictly shorter makespan
    assert!(
        cont.steps.len() < stat.steps.len(),
        "continuous steps {} vs static {}",
        cont.steps.len(),
        stat.steps.len()
    );
    assert!(
        cont.makespan(cont_start) < stat.makespan(stat_start),
        "continuous makespan {:?} must undercut static {:?}",
        cont.makespan(cont_start),
        stat.makespan(stat_start)
    );
    // the occupancy samples flowed through the same Metrics surface the
    // real pool worker reports on
    let snap = cont.metrics.snapshot();
    assert_eq!(snap.decode_steps as usize, cont.steps.len());
    assert_eq!(snap.generations as usize, trace.len());
    assert!(snap.step_occupancy_mean > stat.metrics.snapshot().step_occupancy_mean);
}

// ---------------------------------------------------------------------------
// Step-boundary refresh safety
// ---------------------------------------------------------------------------

#[test]
fn hot_swap_lands_between_steps_with_zero_stale_service() {
    // two long sequences in flight when the modeled drift trigger
    // passes: the swap must land at a step boundary, no drain. The
    // geometry derives from the modeled step time, so the trigger lands
    // mid-generation on any hardware model.
    let probe_clock = Arc::new(VirtualClock::new());
    let probe = SimDecode::new(probe_clock, Arc::new(Metrics::default()), 2, 64, true);
    let st = probe.step_time(2);
    let mut sr = decode_refresh(&["task"], st * 30, st * 3, None);

    let mut sim = SimDecode::new(sr.clock.clone(), sr.metrics.clone(), 2, 64, true);
    let trigger_at = sr.handle.trigger_at("task").expect("modeled trigger");
    let trace = vec![
        DecodeArrival { at: Duration::ZERO, prompt: vec![DECODE_CONTENT; 2], gen_len: 40 },
        DecodeArrival { at: Duration::ZERO, prompt: vec![DECODE_CONTENT; 3], gen_len: 40 },
    ];
    drive_decode(
        &mut sim,
        &sr.registry,
        Some(&sr.handle),
        Some(&mut sr.runner),
        "task",
        &trace,
    );

    // both sequences ran to completion across the swap — drain-free
    assert_eq!(sim.finished.len(), 2);
    for g in &sim.finished {
        assert_eq!(g.tokens, expected_tokens(40), "no sequence was restarted");
        assert_eq!(
            (g.first_version, g.last_version),
            (1, 2),
            "generation {} must start on v1 and finish on v2",
            g.id
        );
    }
    // the crossing is counted exactly once, on the shared Metrics
    assert_eq!(sim.mid_seq_swaps, 1);
    assert_eq!(sr.metrics.mid_seq_swaps.load(Ordering::Relaxed), 1);
    // zero steps served against a stale-past-trigger snapshot
    assert_eq!(sim.stale_steps, 0);
    assert_eq!(
        sim.steps
            .iter()
            .filter(|s| s.at >= trigger_at && s.version < 2)
            .count(),
        0,
        "no post-trigger step may run at the pre-swap version"
    );
    // the swap really did land mid-stream: steps at both versions
    assert!(sim.steps.iter().any(|s| s.version == 1));
    assert!(sim.steps.iter().any(|s| s.version == 2));
}

#[test]
fn step_gate_holds_the_boundary_until_the_swap_lands() {
    let probe_clock = Arc::new(VirtualClock::new());
    let probe = SimDecode::new(probe_clock, Arc::new(Metrics::default()), 2, 64, true);
    let st = probe.step_time(2);
    let mut sr = decode_refresh(&["task"], st * 10, st, None);
    let trigger_at = sr.handle.trigger_at("task").expect("modeled trigger");

    let mut sim = SimDecode::new(sr.clock.clone(), sr.metrics.clone(), 2, 64, true);
    sim.enqueue(vec![DECODE_CONTENT; 2], 40);
    sim.enqueue(vec![DECODE_CONTENT; 2], 40);

    // step WITHOUT ticking the runner until the trigger passes: the
    // gate must defer the boundary instead of serving stale
    let mut held = None;
    for _ in 0..64 {
        match sim.step(&sr.registry, Some(&sr.handle), "task") {
            DecodeOutcome::Progressed => {}
            DecodeOutcome::Held(until) => {
                held = Some(until);
                break;
            }
            DecodeOutcome::Idle => panic!("sequences still in flight"),
        }
    }
    let until = held.expect("the gate must hold once the trigger passes");
    let now = sr.clock.now();
    assert!(now >= trigger_at, "the hold begins only past the trigger");
    assert!(until > now, "the hold is a bounded, future re-check");
    assert_eq!(sim.stale_steps, 0, "the held step never executed");

    // the runner finally ticks: the swap lands BETWEEN steps and the
    // very next boundary serves the new version
    let events = sr.runner.tick(sr.clock.now());
    assert!(!events.is_empty(), "the due refresh must fire");
    assert_eq!(sim.step(&sr.registry, Some(&sr.handle), "task"), DecodeOutcome::Progressed);
    assert_eq!(sim.steps.last().unwrap().version, 2);
    assert_eq!(sim.mid_seq_swaps, 1);

    // run out the tail: still zero stale service end to end
    drive_decode(
        &mut sim,
        &sr.registry,
        Some(&sr.handle),
        Some(&mut sr.runner),
        "task",
        &[],
    );
    assert_eq!(sim.finished.len(), 2);
    assert_eq!(sim.stale_steps, 0);
}

// ---------------------------------------------------------------------------
// Retirement never blocks joiners
// ---------------------------------------------------------------------------

#[test]
fn retire_at_stop_token_never_blocks_joiners() {
    let (clock, registry, metrics) = decode_only("task");
    let mut sim = SimDecode::new(clock, metrics, 2, 32, true);
    let st = sim.step_time(2);
    // both rows busy when the third request arrives; the short row
    // retires first and must hand its slot over at that very boundary
    let trace = vec![
        DecodeArrival { at: Duration::ZERO, prompt: vec![DECODE_CONTENT; 2], gen_len: 2 },
        DecodeArrival { at: Duration::ZERO, prompt: vec![DECODE_CONTENT; 2], gen_len: 8 },
        DecodeArrival { at: st / 2, prompt: vec![DECODE_CONTENT; 2], gen_len: 4 },
    ];
    drive_decode(&mut sim, &registry, None, None, "task", &trace);

    assert_eq!(sim.finished.len(), 3);
    let by_id = |id: u64| sim.finished.iter().find(|g| g.id == id).unwrap();
    let (short, long, joiner) = (by_id(0), by_id(1), by_id(2));
    assert_eq!(short.tokens, expected_tokens(2));
    assert_eq!(joiner.tokens, expected_tokens(4));

    // the joiner's first token came from the boundary immediately after
    // the retirement — one step later, not after the batch drained
    assert!(
        joiner.first_token_at <= short.done_at + st,
        "joiner waited past the freed slot: first token at {:?}, slot freed {:?}",
        joiner.first_token_at,
        short.done_at
    );
    assert!(
        joiner.done_at < long.done_at,
        "the joiner must finish while the long row still decodes"
    );
    // while the joiner decoded, the step-batch stayed full: retirement
    // created no idle-row gap
    assert!(
        sim.steps
            .iter()
            .filter(|s| s.at >= short.done_at && s.at < joiner.done_at)
            .all(|s| s.fill == 2),
        "no under-filled step between the retirement and the joiner's finish"
    );
}

// ---------------------------------------------------------------------------
// Composition with pool-level refresh coordination
// ---------------------------------------------------------------------------

#[test]
fn decode_composes_with_coordinated_staggering() {
    let probe_clock = Arc::new(VirtualClock::new());
    let probe = SimDecode::new(probe_clock, Arc::new(Metrics::default()), 2, 96, true);
    let st = probe.step_time(2);
    // two tasks share one tolerance → identical modeled triggers: the
    // correlated-stall geometry the coordinator exists to fix
    let coord = CoordConfig::default()
        .max_concurrent_holds(1)
        .slack(st * 10)
        .fallback_window(st * 5)
        .fallback_hold(st * 20);
    let mut sr = decode_refresh(&["a", "b"], st * 40, st * 3, Some(coord));

    let mut lane_a = SimDecode::new(sr.clock.clone(), sr.metrics.clone(), 2, 96, true);
    let mut lane_b = SimDecode::new(sr.clock.clone(), sr.metrics.clone(), 2, 96, true);
    for lane in [&mut lane_a, &mut lane_b] {
        lane.enqueue(vec![DECODE_CONTENT; 2], 30);
        lane.enqueue(vec![DECODE_CONTENT; 3], 30);
    }

    // interleave the two lanes on the one shared clock, runner ticking
    // at every boundary — the same discipline as drive_decode
    let mut swap_at: Vec<(String, Instant)> = Vec::new();
    let mut guard = 0;
    loop {
        for ev in sr.runner.tick(sr.clock.now()) {
            swap_at.push((ev.task.clone(), ev.at));
        }
        let ra = lane_a.step(&sr.registry, Some(&sr.handle), "a");
        let rb = lane_b.step(&sr.registry, Some(&sr.handle), "b");
        if ra == DecodeOutcome::Idle && rb == DecodeOutcome::Idle {
            break;
        }
        if ra != DecodeOutcome::Progressed && rb != DecodeOutcome::Progressed {
            sr.clock.advance(st.max(Duration::from_nanos(1)));
        }
        guard += 1;
        assert!(guard < 100_000, "lanes must drain");
    }

    // both swaps landed, at staggered (distinct) instants
    let at = |task: &str| {
        swap_at
            .iter()
            .find(|(t, _)| t == task)
            .map(|(_, a)| *a)
            .expect("swap landed")
    };
    assert_ne!(at("a"), at("b"), "the coordinator must de-correlate the swaps");
    assert!(
        sr.metrics.stagger_shift_ns.load(Ordering::Relaxed) > 0,
        "a stagger re-phase must have been applied"
    );

    // and decode stayed refresh-safe on BOTH lanes through it
    for (name, lane) in [("a", &lane_a), ("b", &lane_b)] {
        assert_eq!(lane.finished.len(), 2, "lane {name}");
        assert_eq!(lane.stale_steps, 0, "lane {name} served stale steps");
        assert!(lane.mid_seq_swaps >= 1, "lane {name} never crossed its swap");
        for g in &lane.finished {
            assert_eq!(g.tokens, expected_tokens(30));
            assert_eq!(g.first_version, 1, "lane {name}");
            assert!(
                g.last_version > g.first_version,
                "lane {name}: generation {} must finish on a newer version",
                g.id
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Release-lane storm
// ---------------------------------------------------------------------------

/// 8 decode lanes × long sequences × one shared drift tolerance: the
/// decode invariants (zero stale steps, drain-free crossings, full
/// completion) must hold at pool scale. Virtual clock throughout — the
/// gate exists because the step count, not wall time, is what makes
/// this slow in debug builds.
#[test]
fn eight_worker_long_sequence_decode_stress() {
    if !release_only() {
        return;
    }
    const WORKERS: usize = 8;
    let tasks = ["t0", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];

    let probe_clock = Arc::new(VirtualClock::new());
    let probe = SimDecode::new(probe_clock, Arc::new(Metrics::default()), 4, 128, true);
    let st = probe.step_time(4);
    let mut sr = decode_refresh(&tasks, st * 800, st * 5, None);

    let mut lanes: Vec<SimDecode> = (0..WORKERS)
        .map(|_| SimDecode::new(sr.clock.clone(), sr.metrics.clone(), 4, 128, true))
        .collect();
    let traces: Vec<Vec<DecodeArrival>> = (0..WORKERS)
        .map(|w| decode_trace(16, st * (2 + w as u32 % 3), &[24, 56, 32, 48, 40]))
        .collect();

    let t0 = sr.clock.now();
    let mut next = vec![0usize; WORKERS];
    let mut guard = 0usize;
    loop {
        sr.runner.tick(sr.clock.now());
        let mut any_progress = false;
        let mut all_idle = true;
        for w in 0..WORKERS {
            while next[w] < traces[w].len() && t0 + traces[w][next[w]].at <= sr.clock.now() {
                let a = &traces[w][next[w]];
                lanes[w].enqueue(a.prompt.clone(), a.gen_len);
                next[w] += 1;
            }
            match lanes[w].step(&sr.registry, Some(&sr.handle), tasks[w]) {
                DecodeOutcome::Progressed => {
                    any_progress = true;
                    all_idle = false;
                }
                DecodeOutcome::Held(_) => all_idle = false,
                DecodeOutcome::Idle => {}
            }
        }
        let arrivals_left = next.iter().zip(&traces).any(|(&n, t)| n < t.len());
        if all_idle && !arrivals_left && lanes.iter().all(|l| !l.busy()) {
            break;
        }
        if !any_progress {
            sr.clock.advance(st.max(Duration::from_nanos(1)));
        }
        guard += 1;
        assert!(guard < 2_000_000, "the storm must drain");
    }

    let mut crossings = 0;
    for (w, lane) in lanes.iter().enumerate() {
        assert_eq!(lane.finished.len(), 16, "lane {w} completed every request");
        assert_eq!(lane.stale_steps, 0, "lane {w} served stale steps");
        assert!(
            lane.occupancy() > 0.6,
            "lane {w} occupancy collapsed: {:.3}",
            lane.occupancy()
        );
        for g in &lane.finished {
            assert_eq!(
                g.tokens,
                expected_tokens(traces[w][g.id as usize].gen_len),
                "lane {w} generation {}",
                g.id
            );
        }
        crossings += lane.mid_seq_swaps;
    }
    assert!(
        crossings >= WORKERS as u64,
        "every lane must cross its hot-swap mid-sequence (saw {crossings})"
    );
    assert_eq!(
        sr.metrics.generations.load(Ordering::Relaxed),
        (WORKERS * 16) as u64
    );
    assert_eq!(
        sr.metrics.mid_seq_swaps.load(Ordering::Relaxed),
        crossings
    );
}
