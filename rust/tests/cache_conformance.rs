//! Conformance suite for the bounded adapter capacity tier
//! (`serve::cache`), on the shared `tests/common/refresh_sim.rs`
//! harness — ONE `VirtualClock` under a demand trace with many more
//! tasks than DPU adapter memory, zero real-time sleeps. The
//! [`refresh_sim::CacheSim`] drive asserts residency invariants after
//! EVERY event, so "at every instant" pins are exact, not sampled.
//!
//! Pinned:
//!
//! * **Capacity bound.** Under a 64-task zipf trace with capacity 8,
//!   the number of resident adapters never exceeds 8 at any instant —
//!   and the bound is actually reached (the tier runs full, it does
//!   not hide behind under-use).
//! * **Pin stability.** A pinned task, once resident, is never chosen
//!   for eviction — through an admission storm and a full demand trace.
//! * **Typed cold shed.** When the bounded load queue fills, cold
//!   requests shed with the typed, retryable
//!   [`ServeError::AdapterCold`] — every trace request is accounted as
//!   served or shed, never silently dropped.
//! * **Refresh integration.** An evicted task is never refit (no refit
//!   of a paged-out adapter), and a reload restores the SAME version so
//!   the drift anchor survives: the modeled trigger instant is
//!   unchanged across evict → reload, and a task whose substrate
//!   drifted past tolerance while paged out refits immediately after
//!   the reload.
//! * **Prefetch wins.** On a periodic trace the arrival-EWMA
//!   prefetcher strictly improves cold-start p99 (and hit rate) over
//!   the same cache with prefetch disabled — the number the predictive
//!   tier exists to cut.
//!
//! The release-only eviction-storm variant (128 tasks, 64k requests)
//! re-checks the capacity and accounting invariants under sustained
//! churn; `./ci.sh test-release` runs it.

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    AdapterCache, CacheConfig, CacheLookup, Clock, DecayModel, FnRefitter, Metrics, Refit,
    Refitter, ServeError, VirtualClock,
};
use refresh_sim::{adapter, analytic_runner, cache_sim, periodic_trace, zipf_trace};

#[test]
fn residency_never_exceeds_capacity_at_any_instant_under_a_64_task_trace() {
    let mut sim = cache_sim(
        64,
        CacheConfig::new(8)
            .load_latency(Duration::from_micros(200))
            .prefetch(false),
    );
    let trace = zipf_trace(4096, 64, 7);
    // the drive asserts resident <= capacity after EVERY poll/lookup
    sim.drive(&trace, Duration::from_micros(250));

    assert_eq!(sim.max_resident, 8, "the tier runs full, never over");
    assert_eq!(sim.served + sim.shed, 4096, "every request accounted");
    assert!(
        sim.metrics.cache_evictions.load(Ordering::Relaxed) > 0,
        "a 64-task trace over 8 slots must churn"
    );
    assert!(
        sim.hit_rate() > 0.2,
        "the zipf head stays near-resident, got hit rate {}",
        sim.hit_rate()
    );
}

#[test]
fn pinned_tasks_survive_a_full_demand_trace() {
    let mut sim = cache_sim(
        16,
        CacheConfig::new(4)
            .pin("task00")
            .pin("task01")
            .load_latency(Duration::from_micros(100)),
    );
    assert!(sim.cache.is_resident("task00") && sim.cache.is_resident("task01"));
    // the drive asserts pin residency after every event
    sim.drive(&periodic_trace(512, 16), Duration::from_micros(200));
    assert!(
        sim.cache.is_resident("task00") && sim.cache.is_resident("task01"),
        "pins outlive the churn"
    );
    assert!(sim.metrics.cache_evictions.load(Ordering::Relaxed) > 0);
}

#[test]
fn cold_requests_past_the_load_queue_shed_typed_never_silently() {
    // loads are 10 arrivals long and at most 2 may be in flight: the
    // 12-task round-robin overruns the channel constantly
    let mut sim = cache_sim(
        12,
        CacheConfig::new(2)
            .load_queue(2)
            .load_latency(Duration::from_millis(1))
            .prefetch(false),
    );
    sim.drive(&periodic_trace(240, 12), Duration::from_micros(100));

    assert!(sim.shed > 0, "the bounded queue did fill");
    assert_eq!(sim.served + sim.shed, 240, "shed is accounted, not dropped");
    assert_eq!(
        sim.metrics.cache_shed.load(Ordering::Relaxed),
        sim.shed as u64,
        "every shed moved the typed counter"
    );

    // the typed error the serving surface maps a Shed to: retryable
    // (capacity pressure is transient), and distinct from UnknownTask
    let shed = ServeError::AdapterCold {
        task: "task03".to_string(),
        loading: false,
    };
    assert!(shed.is_retryable());
    assert!(shed.to_string().contains("load queue full"));
    let loading = ServeError::AdapterCold {
        task: "task03".to_string(),
        loading: true,
    };
    assert!(loading.is_retryable());
    assert!(loading.to_string().contains("paged out"));
}

#[test]
fn refresh_never_refits_evicted_and_reload_keeps_the_drift_anchor() {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    let metrics = Arc::new(Metrics::default());

    let tolerance = 0.05;
    let trigger_in = Duration::from_millis(100);
    let age = DecayModel::analytic(PcmModel::default()).trigger_age(tolerance);
    let time_scale = age / trigger_in.as_secs_f64();
    let refitter: Arc<dyn Refitter> = Arc::new(FnRefitter(
        |_: &str, current: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
            Ok(Refit {
                params: adapter(current.tensors[0].data[0] + 1.0),
                steps: budget,
            })
        },
    ));
    let mut runner = analytic_runner(&registry, refitter, tolerance, time_scale, metrics.clone())
        .with_clock(clock.clone() as Arc<dyn Clock>);

    let cache = AdapterCache::new(
        CacheConfig::new(2)
            .load_latency(Duration::from_millis(1))
            .prefetch(false),
        registry.clone(),
        clock.clone() as Arc<dyn Clock>,
        metrics.clone(),
    );
    for t in ["a", "b", "c"] {
        registry.deploy(t, adapter(1.0));
    }
    runner.track_deployed(clock.now());
    let handle = runner.policy().handle();
    cache.set_refresh(handle.clone());
    let anchor = handle.trigger_at("a").expect("tracked task has a trigger");

    // capacity 2 over 3 tasks: draining the admission queue pages "a"
    // (the LRU of the initial set) out, with the refresh handle attached
    cache.poll(clock.now());
    assert!(!cache.is_resident("a") && registry.is_evicted("a"));
    assert!(handle.is_evicted("a"), "eviction reached the lifecycle");

    // past the modeled trigger: b and c refit, the paged-out "a" does
    // NOT (no refit of an adapter that is not on the DPUs) — and it
    // accumulates no stale debt it cannot act on
    clock.advance(trigger_in + Duration::from_millis(1));
    let events = runner.tick(clock.now());
    assert_eq!(events.len(), 2, "both resident tasks refit");
    assert!(
        events.iter().all(|e| e.task != "a"),
        "evicted task was refit"
    );
    assert!(
        !handle.is_stale("a", 1, clock.now()),
        "evicted tasks carry no stale debt"
    );

    // demand reload: same bytes, SAME version — so the reconciler
    // recognises the deployment and the drift anchor survives
    let now = clock.now();
    assert!(matches!(cache.lookup("a", now, 1), CacheLookup::Queued { .. }));
    clock.advance(Duration::from_millis(1));
    let landed = cache.poll(clock.now());
    assert!(landed.contains(&"a".to_string()));
    assert_eq!(registry.version("a"), Some(1), "reload is not a deploy");
    assert!(!handle.is_evicted("a"));
    assert_eq!(
        handle.trigger_at("a"),
        Some(anchor),
        "evict → reload must not re-anchor the drift clock"
    );

    // the substrate drifted the whole time the adapter was paged out:
    // back past its unchanged trigger, it refits on the next check
    let events = runner.tick(clock.now());
    assert_eq!(events.len(), 1, "exactly the reloaded task is due");
    assert_eq!(events[0].task, "a");
    assert_eq!(registry.version("a"), Some(2), "immediate catch-up refit");
}

#[test]
fn prefetch_strictly_improves_cold_start_p99_over_lru_only() {
    // 16 tasks on a strict 16 ms period over 8 slots: plain LRU evicts
    // every adapter ~8 ms before its next use, so steady state is a
    // 100% demand-miss thrash — while the EWMA predictor sees every
    // arrival coming 2 ms out, far longer than the 200 µs upload
    let base = || {
        CacheConfig::new(8)
            .load_latency(Duration::from_micros(200))
            .prefetch_horizon(Duration::from_millis(2))
    };
    let trace = periodic_trace(8192, 16);
    let ia = Duration::from_millis(1);

    let mut off = cache_sim(16, base().prefetch(false));
    off.drive(&trace, ia);
    let mut on = cache_sim(16, base().prefetch(true));
    on.drive(&trace, ia);

    assert!(
        off.cold_p99_ms() > 0.0,
        "the baseline does thrash (cold p99 {})",
        off.cold_p99_ms()
    );
    assert!(
        on.cold_p99_ms() < off.cold_p99_ms(),
        "prefetch must strictly improve cold-start p99: on {} vs off {}",
        on.cold_p99_ms(),
        off.cold_p99_ms()
    );
    assert!(
        on.hit_rate() > off.hit_rate() + 0.5,
        "predicted page-ins convert the thrash to hits: on {} vs off {}",
        on.hit_rate(),
        off.hit_rate()
    );
    assert!(
        on.metrics.cache_prefetch_hits.load(Ordering::Relaxed) > 0,
        "hits attribute to the prefetcher"
    );
    assert_eq!(on.served + on.shed, trace.len());
    assert_eq!(off.served + off.shed, trace.len());
}

/// Release-only eviction storm: 128 tasks over 8 slots, 64k zipf
/// requests — the capacity and accounting invariants under sustained
/// churn (the per-event invariant asserts run 128k+ times). Debug
/// builds skip it; `./ci.sh test-release` runs it.
#[test]
fn eviction_storm_holds_every_invariant() {
    if cfg!(debug_assertions) {
        return;
    }
    let mut sim = cache_sim(
        128,
        CacheConfig::new(8)
            .load_latency(Duration::from_micros(100))
            .prefetch(false),
    );
    let n = 65_536;
    let trace = zipf_trace(n, 128, 11);
    sim.drive(&trace, Duration::from_micros(150));

    assert_eq!(sim.max_resident, 8);
    assert_eq!(sim.served + sim.shed, n);
    assert!(
        sim.metrics.cache_evictions.load(Ordering::Relaxed) > 1_000,
        "a storm, not a trickle: {} evictions",
        sim.metrics.cache_evictions.load(Ordering::Relaxed)
    );
}
