//! Property tests for the refresh-coupled batch scheduler, in the
//! `pcm_properties.rs` style (in-tree `util::proptest`, hermetic, no
//! artifacts, no sleeps).
//!
//! Pinned invariants, for arbitrary arrival rates, fills, window/hold
//! geometries, and drift pressures:
//! * the chosen fill is monotone non-increasing in drift pressure and
//!   never escapes `[1, max_batch]`,
//! * effective deadlines are monotone non-increasing in pressure and —
//!   in particular while a refit is in flight (pressure saturated at
//!   1) — never move later than the uncoupled `head + max_wait`,
//! * a refit observed mid-flight through the shared `RefreshHandle`
//!   saturates drift pressure at exactly 1,
//! * `RefreshCoupling` can never be constructed invalid: the defaults
//!   satisfy the invariants (window > 0, hold > 0, min_fill ≥ 1,
//!   deadline_factor ∈ [0, 1], post_swap_factor ≥ 1) and every
//!   builder setter clamps arbitrary inputs back inside them — which
//!   is what lets the pool coordinator feed *adaptive* window/hold
//!   values through without ever producing a degenerate coupling,
//! * the arrival estimator's cold-start rule: with fewer than two
//!   observed arrivals `interarrival_ns` clamps the unknown (+inf)
//!   estimate to `max_wait` — an actionable fill, never degenerate
//!   patience — while measured EWMAs pass through unclamped and only
//!   measured tasks are exported to the cache prefetcher.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ahwa_lora::model::params::{ParamStore, Tensor};
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    BatchScheduler, DecayModel, FnRefitter, Metrics, Refit, RefreshConfig, RefreshCoupling,
    RefreshRunner, SchedConfig, VirtualClock,
};
use ahwa_lora::util::proptest::check;

fn sched_with(coupling: RefreshCoupling, max_batch: usize, max_wait: Duration) -> BatchScheduler {
    BatchScheduler::new(
        SchedConfig::for_layer(128, 128, 8).seq(320).coupling(coupling),
        max_batch,
        max_wait,
    )
}

/// The coupling invariants adaptive (coordinator-fed) values rely on.
fn assert_coupling_valid(c: &RefreshCoupling) {
    assert!(c.window > Duration::ZERO, "window must be positive");
    assert!(c.hold > Duration::ZERO, "hold must be positive");
    assert!(c.min_fill >= 1, "min_fill must admit at least one request");
    assert!(
        (0.0..=1.0).contains(&c.deadline_factor),
        "deadline_factor escaped [0, 1]: {}",
        c.deadline_factor
    );
    assert!(
        c.post_swap_factor >= 1.0,
        "the post-swap boost may never SHRINK fills: {}",
        c.post_swap_factor
    );
}

#[test]
fn coupling_defaults_and_setter_round_trips_never_construct_invalid_state() {
    assert_coupling_valid(&RefreshCoupling::default());

    check("coupling-setter-round-trips", 64, |g| {
        // arbitrary (including degenerate) inputs through every setter
        let window = g.duration_in(Duration::ZERO, Duration::from_secs(2));
        let hold = g.duration_in(Duration::ZERO, Duration::from_secs(2));
        let post_window = g.duration_in(Duration::ZERO, Duration::from_secs(2));
        let min_fill = g.usize_in(0, 64);
        let deadline = g.f64_in(-2.0, 3.0);
        let boost = g.f64_in(-2.0, 8.0);
        let c = RefreshCoupling::default()
            .window(window)
            .hold(hold)
            .post_swap_window(post_window)
            .min_fill(min_fill)
            .deadline_factor(deadline)
            .post_swap_factor(boost);
        assert_coupling_valid(&c);

        // round trips: in-range inputs are stored verbatim...
        if window > Duration::ZERO {
            assert_eq!(c.window, window);
        }
        if hold > Duration::ZERO {
            assert_eq!(c.hold, hold);
        }
        assert_eq!(c.post_swap_window, post_window);
        if min_fill >= 1 {
            assert_eq!(c.min_fill, min_fill);
        }
        if (0.0..=1.0).contains(&deadline) {
            assert_eq!(c.deadline_factor, deadline);
        }
        if boost >= 1.0 {
            assert_eq!(c.post_swap_factor, boost);
        }
        // ...and out-of-range ones clamp to the nearest valid value
        assert_eq!(
            RefreshCoupling::default().window(Duration::ZERO).window,
            RefreshCoupling::MIN_PHASE
        );
        assert_eq!(
            RefreshCoupling::default().hold(Duration::ZERO).hold,
            RefreshCoupling::MIN_PHASE
        );
        assert_eq!(RefreshCoupling::default().min_fill(0).min_fill, 1);
        assert_eq!(
            RefreshCoupling::default().deadline_factor(7.0).deadline_factor,
            1.0
        );
        assert_eq!(
            RefreshCoupling::default().post_swap_factor(0.0).post_swap_factor,
            1.0
        );
    });
}

#[test]
fn coupled_fill_is_monotone_in_pressure_and_never_escapes_bounds() {
    check("coupled-fill-monotone", 48, |g| {
        let max_batch = g.usize_in(1, 16);
        let coupling = RefreshCoupling::default()
            .min_fill(g.usize_in(1, 16))
            .deadline_factor(g.f64_in(0.0, 1.0))
            .window(g.duration_in(Duration::from_micros(1), Duration::from_millis(500)));
        let s = sched_with(coupling, max_batch, Duration::from_millis(5));

        // targets both inside and beyond max_batch must clamp
        let target = g.usize_in(1, 2 * max_batch);
        let mut last = usize::MAX;
        for i in 0..=16 {
            let fill = s.coupled_fill(target, i as f64 / 16.0);
            assert!(
                (1..=max_batch).contains(&fill),
                "fill {fill} escaped [1, {max_batch}]"
            );
            assert!(
                fill <= last,
                "fill must be monotone non-increasing in drift pressure"
            );
            last = fill;
        }

        // arbitrary arrival rates (including unknown/+inf and bursty/0):
        // the pressure-shaped target obeys the same bounds
        let ia = if g.bool() { g.f64_in(0.0, 1e9) } else { f64::INFINITY };
        let p = g.f64_in(0.0, 1.0);
        let shaped = s.coupled_fill(s.target_fill(ia), p);
        assert!((1..=max_batch).contains(&shaped));
    });
}

#[test]
fn coupled_deadlines_are_monotone_and_never_later_than_uncoupled() {
    check("coupled-deadline-never-later", 48, |g| {
        let max_wait = g.duration_in(Duration::from_micros(10), Duration::from_millis(50));
        let coupling = RefreshCoupling::default()
            .deadline_factor(g.f64_in(0.0, 1.0))
            .hold(g.duration_in(Duration::ZERO, Duration::from_millis(10)));
        let s = sched_with(coupling, g.usize_in(1, 16), max_wait);

        let clock = VirtualClock::new();
        clock.advance(g.duration_in(Duration::ZERO, Duration::from_secs(60)));
        let head = clock.now();
        let base = head + max_wait;
        let mut last = base + Duration::from_secs(1);
        for i in 0..=16 {
            let d = s.coupled_deadline(head, i as f64 / 16.0);
            assert!(
                d <= base,
                "a coupled deadline may never move later than head + max_wait"
            );
            assert!(d >= head, "a deadline can tighten at most to the head");
            assert!(d <= last, "deadline monotone non-increasing in pressure");
            last = d;
        }
        // saturated pressure is exactly the refit-in-flight case
        assert!(s.coupled_deadline(head, 1.0) <= base);
    });
}

#[test]
fn refit_in_flight_saturates_pressure_and_keeps_deadlines_early() {
    check("refit-in-flight-pressure", 24, |g| {
        let clock = Arc::new(VirtualClock::new());
        let registry = SharedRegistry::new();
        registry.deploy(
            "t",
            ParamStore::from_tensors(vec![Tensor::zeros("a", &[1])]),
        );

        let max_wait = g.duration_in(Duration::from_micros(50), Duration::from_millis(20));
        let max_batch = g.usize_in(1, 12);
        // window strictly inside the (compressed, ~1ms) trigger lead so
        // the post-swap re-anchored trigger sits outside it again
        let coupling = RefreshCoupling::default()
            .deadline_factor(g.f64_in(0.0, 1.0))
            .min_fill(g.usize_in(1, 12))
            .window(g.duration_in(Duration::from_micros(1), Duration::from_micros(500)));

        // heads at random ages behind "now" to probe deadlines with
        let head_ages: Vec<Duration> = (0..4)
            .map(|_| g.duration_in(Duration::ZERO, max_wait * 3))
            .collect();

        // compress the modeled trigger to ~1ms of pool clock
        let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
        let slot: Arc<Mutex<Option<Arc<BatchScheduler>>>> = Arc::new(Mutex::new(None));
        let fired = Arc::new(AtomicBool::new(false));
        let refitter = {
            let (slot, fired, clock, head_ages) =
                (slot.clone(), fired.clone(), clock.clone(), head_ages.clone());
            let (max_wait_c, max_batch_c) = (max_wait, max_batch);
            Arc::new(FnRefitter(
                move |task: &str,
                      _: &ParamStore,
                      _: &ParamStore,
                      budget: usize|
                      -> anyhow::Result<Refit> {
                    // observed MID-REFIT, through the shared handle:
                    let s = slot.lock().unwrap().clone().expect("scheduler published");
                    let now = clock.now();
                    assert_eq!(
                        s.drift_pressure(task, now),
                        1.0,
                        "a refit in flight saturates drift pressure"
                    );
                    for &age in &head_ages {
                        let head = now - age;
                        assert!(
                            s.coupled_deadline(head, s.drift_pressure(task, now))
                                <= head + max_wait_c,
                            "deadlines never move later while a refit is in flight"
                        );
                    }
                    let fill = s.coupled_fill(max_batch_c, s.drift_pressure(task, now));
                    assert!((1..=max_batch_c).contains(&fill));
                    fired.store(true, Ordering::Relaxed);
                    Ok(Refit {
                        params: ParamStore::from_tensors(vec![Tensor::zeros("a", &[1])]),
                        steps: budget,
                    })
                },
            ))
        };
        let rcfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), refitter)
            .tolerance(0.05)
            .time_scale(age / 1e-3);
        let mut runner = RefreshRunner::new(
            rcfg,
            registry.clone(),
            Arc::new(ParamStore::default()),
            Arc::new(Metrics::default()),
        );
        runner.track_deployed(clock.now());
        let s = Arc::new(
            sched_with(coupling, max_batch, max_wait).with_refresh(runner.policy().handle()),
        );
        *slot.lock().unwrap() = Some(s.clone());

        // past the trigger: the tick runs the (asserting) refit inline
        clock.advance(Duration::from_millis(1) + Duration::from_micros(10));
        let events = runner.tick(clock.now());
        assert_eq!(events.len(), 1, "the refit ran");
        assert!(fired.load(Ordering::Relaxed), "mid-refit assertions executed");
        // after the swap the pressure relaxes back to zero
        assert_eq!(s.drift_pressure("t", clock.now()), 0.0);
    });
}

#[test]
fn cold_start_estimates_clamp_to_max_wait_and_measured_rates_pass_through() {
    check("cold-start-clamp", 64, |g| {
        let max_wait = g.duration_in(Duration::from_micros(10), Duration::from_millis(50));
        let mut s = sched_with(RefreshCoupling::default(), g.usize_in(1, 16), max_wait);
        let clamp = max_wait.as_nanos() as f64;

        // never-seen task: the raw EWMA is +inf — the scheduler must
        // report the deadline clamp, not a degenerate infinite patience
        assert_eq!(s.interarrival_ns("never"), clamp);

        let clock = VirtualClock::new();
        clock.advance(g.duration_in(Duration::ZERO, Duration::from_secs(60)));

        // ONE observed arrival measures no gap: still the clamp, and
        // the prefetch export omits the task rather than fabricating a
        // rate from the clamp
        s.observe_arrival("t", clock.now());
        assert_eq!(s.interarrival_ns("t"), clamp);
        assert!(
            s.arrival_rates().iter().all(|(task, _)| task != "t"),
            "no ArrivalRate before the EWMA has a measured gap"
        );
        let fill = s.target_fill(s.interarrival_ns("t"));
        assert!(fill >= 1, "the clamped estimate yields an actionable fill");

        // the SECOND arrival seeds the EWMA from the first observed gap:
        // the measured rate passes through unclamped — including rates
        // genuinely slower than the deadline
        let gap = g.duration_in(Duration::from_micros(1), max_wait * 4);
        clock.advance(gap);
        s.observe_arrival("t", clock.now());
        let est = s.interarrival_ns("t");
        assert!(est.is_finite());
        assert!(
            (est - gap.as_nanos() as f64).abs() <= 1.0,
            "EWMA seeds from the first gap: est {est} vs gap {:?}",
            gap
        );
        let rates = s.arrival_rates();
        let (_, rate) = rates
            .iter()
            .find(|(task, _)| task == "t")
            .expect("measured task is exported to the prefetcher");
        assert_eq!(rate.predicted_next(), rate.last + rate.interarrival);
    });
}
