//! Shared virtual-clock harness for the refresh ↔ scheduler coupling:
//! the SAME deploy → serve → drift → refresh → hot-swap scenario backs
//! both the conformance suite (`tests/refresh_sched_e2e.rs`) and the
//! stale-request bench (`benches/serving_refresh_sched.rs`), so the
//! coupling contract is single-sourced and cannot silently diverge
//! between the two.
//!
//! The simulated worker mirrors the pool's worker loop: arrivals feed
//! the rate estimator and the batcher, the refresh runner ticks on a
//! deterministic cadence (every arrival), and each popped batch
//! "executes" for its modeled pipeline latency. Arrivals are paced so
//! the modeled-optimal fill is `MAX_BATCH`, and the run is positioned
//! so the modeled drift trigger lands mid-stream.

// Consumed by two separate crates (a test and a bench) that each use a
// different subset of the harness surface.
#![allow(dead_code)]

use std::sync::Arc;
use std::time::{Duration, Instant};

use ahwa_lora::model::params::{ParamStore, Tensor};
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::batcher::Batcher;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    BatchScheduler, Clock, DecayModel, Decision, FnRefitter, Metrics, Refit, RefreshConfig,
    RefreshCoupling, RefreshRunner, SchedConfig, VirtualClock,
};

pub const MAX_BATCH: usize = 8;

/// Stream length the conformance tests use (the bench runs longer).
pub const N_REQUESTS_DEFAULT: usize = 512;

/// Single-tensor adapter whose payload tags the deployment.
pub fn adapter(tag: f32) -> ParamStore {
    ParamStore::from_tensors(vec![Tensor {
        name: "lora.a".to_string(),
        shape: vec![1],
        data: vec![tag],
    }])
}

/// One simulated served batch: pop instant, modeled completion, fill,
/// and the adapter version its registry snapshot pinned.
pub struct SimBatch {
    pub popped_at: Instant,
    pub done_at: Instant,
    pub fill: usize,
    pub version: u64,
}

pub struct SimRun {
    pub batches: Vec<SimBatch>,
    /// Per-request modeled latency (enqueue → modeled completion), ns.
    pub lat_ns: Vec<f64>,
    /// Modeled tolerance-crossing instant of the initial deployment.
    pub trigger_at: Instant,
    /// When the refresh hot-swap actually landed in the registry.
    pub swap_at: Instant,
    pub swap_version: u64,
    /// Pressure-shaped (`Decision::Drain`) closes observed.
    pub drains: usize,
    /// `Decision::Hold` deferrals observed.
    pub holds: usize,
}

impl SimRun {
    pub fn served(&self) -> usize {
        self.batches.iter().map(|b| b.fill).sum()
    }

    /// Requests that completed after the modeled trigger while still on
    /// the pre-refresh adapter version — the stale-service count the
    /// coupling must drive to zero.
    pub fn stale_after_trigger(&self) -> usize {
        self.batches
            .iter()
            .filter(|b| b.version < self.swap_version && b.done_at > self.trigger_at)
            .map(|b| b.fill)
            .sum()
    }

    /// Batches whose modeled service interval contains the swap.
    pub fn spanning_batches(&self) -> usize {
        self.batches
            .iter()
            .filter(|b| b.popped_at < self.swap_at && b.done_at > self.swap_at)
            .count()
    }

    /// First batch popped at or after the swap instant.
    pub fn first_post_swap(&self) -> Option<&SimBatch> {
        self.batches.iter().find(|b| b.popped_at >= self.swap_at)
    }

    /// Registry-swap → first-serve gap (zero when nothing served after
    /// the swap).
    pub fn swap_gap(&self) -> Duration {
        self.first_post_swap()
            .map(|b| b.popped_at.saturating_duration_since(self.swap_at))
            .unwrap_or_default()
    }
}

/// Drive the full cycle on the virtual clock. `coupled` switches the
/// scheduler's refresh coupling on; the refresh runner itself runs
/// identically in both modes.
pub fn simulate(coupled: bool, n_requests: usize) -> SimRun {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    registry.deploy("task", adapter(1.0));

    let rcfg = RefreshConfig::new(
        DecayModel::analytic(PcmModel::default()),
        Arc::new(FnRefitter(
            |_: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
                Ok(Refit {
                    params: adapter(2.0),
                    steps: budget,
                })
            },
        )),
    )
    .tolerance(0.05);
    let mut runner = RefreshRunner::new(
        rcfg,
        registry.clone(),
        Arc::new(ParamStore::default()),
        Arc::new(Metrics::default()),
    );
    runner.track_deployed(clock.now());
    let handle = runner.policy().handle();
    let trigger_secs = runner.policy().trigger_age_secs("task").expect("finite trigger");

    let max_wait = Duration::from_millis(5);
    // derive pacing from an uncoupled probe of the same hardware model
    let probe = BatchScheduler::new(
        SchedConfig::for_layer(128, 128, 8).seq(320),
        MAX_BATCH,
        max_wait,
    );
    let per = |b: usize| probe.modeled_batch_ns(b) / b as f64;
    // arrivals twice as fast as a full batch's per-request service
    // time: no fill sustains the rate, so the modeled-optimal fill is
    // MAX_BATCH and the queue never goes idle mid-run
    let ia = Duration::from_nanos((per(MAX_BATCH) / 2.0).round() as u64);

    let mut scfg = SchedConfig::for_layer(128, 128, 8).seq(320);
    if coupled {
        scfg = scfg.coupling(
            RefreshCoupling::default()
                .window(ia * 64)
                .hold(max_wait)
                .post_swap_window(ia * 64),
        );
    }
    let mut sched = BatchScheduler::new(scfg, MAX_BATCH, max_wait).with_refresh(handle.clone());

    // position the run so the trigger lands mid-stream
    let half_span = ia * (n_requests as u32 / 2);
    clock.advance(Duration::from_secs_f64(trigger_secs) - half_span);
    let trigger_at = handle.trigger_at("task").expect("modeled trigger");

    let mut batcher: Batcher<Instant> =
        Batcher::with_clock(MAX_BATCH, max_wait, clock.clone() as Arc<dyn Clock>);
    let mut run = SimRun {
        batches: Vec::new(),
        lat_ns: Vec::with_capacity(n_requests),
        trigger_at,
        swap_at: trigger_at,
        swap_version: 1,
        drains: 0,
        holds: 0,
    };

    // the simulated worker's pop loop: serve every ready batch, record
    // its modeled service span and pinned adapter version
    let drain = |sched: &BatchScheduler, batcher: &mut Batcher<Instant>, run: &mut SimRun| {
        loop {
            let now = clock.now();
            let (task, fill, drained) = match sched.pick(batcher, now) {
                Decision::Close { task, fill } => (task, fill, false),
                Decision::Drain { task, fill } => (task, fill, true),
                Decision::Hold { .. } => {
                    run.holds += 1;
                    break;
                }
                Decision::Wait { .. } | Decision::Idle => break,
            };
            if drained {
                run.drains += 1;
            }
            let reqs = batcher.pop_task(&task, fill).expect("ready batch");
            assert_eq!(reqs.len(), fill, "pop honours the decided fill");
            let (_, version) = registry.snapshot(&task).expect("deployed");
            let done_at = now + sched.modeled_batch(fill);
            for enqueued in &reqs {
                run.lat_ns
                    .push(done_at.saturating_duration_since(*enqueued).as_nanos() as f64);
            }
            run.batches.push(SimBatch {
                popped_at: now,
                done_at,
                fill,
                version,
            });
        }
    };

    for _ in 0..n_requests {
        clock.advance(ia);
        let now = clock.now();
        // the background refresh worker's check cadence: every arrival
        for ev in runner.tick(now) {
            run.swap_at = ev.at;
            run.swap_version = ev.version;
        }
        sched.observe_arrival("task", now);
        batcher.push("task", now);
        drain(&sched, &mut batcher, &mut run);
    }
    // flush the tail past any deadline/hold, refresh still ticking
    let mut rounds = 0;
    while batcher.pending() > 0 {
        clock.advance(max_wait);
        for ev in runner.tick(clock.now()) {
            run.swap_at = ev.at;
            run.swap_version = ev.version;
        }
        drain(&sched, &mut batcher, &mut run);
        rounds += 1;
        assert!(rounds < 64, "tail must drain");
    }
    assert_eq!(run.lat_ns.len(), n_requests, "every request served");
    run
}
