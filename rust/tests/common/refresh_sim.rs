//! Shared virtual-clock harness for the refresh ↔ scheduler ↔
//! coordinator stack: the SAME deploy → serve → drift → refresh →
//! hot-swap machinery backs the single-worker coupling conformance
//! suite (`tests/refresh_sched_e2e.rs`), the cross-worker coordination
//! suite (`tests/coord_conformance.rs`), the stale-request bench
//! (`benches/serving_refresh_sched.rs`), the runner spin-up of the
//! stress suite (`tests/refresh_stress.rs`), and the capacity-tier
//! suite and bench (`tests/cache_conformance.rs`,
//! `benches/serving_cache.rs`) — so the coupling, coordination, and
//! residency contracts are single-sourced and cannot silently diverge
//! between suites.
//!
//! [`SimPool`] mirrors the real pool's worker loop, N workers wide, on
//! ONE shared `VirtualClock`: arrivals feed each worker's rate
//! estimator and batcher, the refresh runner ticks on a deterministic
//! cadence, refits consume a configurable amount of *virtual* time (the
//! modeled step budget), and each popped batch "executes" for its
//! modeled pipeline latency. Tasks are assigned to workers round-robin,
//! so a "≥ 4 workers, 4 tasks, one shared tolerance" scenario is
//! exactly the correlated-stall geometry the pool coordinator
//! ([`ahwa_lora::serve::coord`]) exists to fix.

// Consumed by several separate crates (tests and a bench) that each use
// a different subset of the harness surface.
#![allow(dead_code)]

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use ahwa_lora::model::params::{ParamStore, Tensor};
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::batcher::Batcher;
use ahwa_lora::serve::hal::route_one;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    drift_free, step_gate, AdapterCache, Backend, BackendProfile, BatchScheduler, CacheConfig,
    CacheLookup, Clock, CoordConfig, DecayModel, Decision, FnRefitter, Metrics, PlannedMove,
    Refit, Refitter, RebalanceConfig, RebalanceRunner, RefreshConfig, RefreshCoordinator,
    RefreshCoupling, RefreshHandle, RefreshRunner, Router, SchedConfig, StepEngine, StepGate,
    VirtualClock,
};
use ahwa_lora::util::rng::Pcg64;
use ahwa_lora::util::stats;

pub const MAX_BATCH: usize = 8;

/// Stream length the single-worker conformance tests use (the bench
/// runs longer).
pub const N_REQUESTS_DEFAULT: usize = 512;

/// Single-tensor adapter whose payload tags the deployment.
pub fn adapter(tag: f32) -> ParamStore {
    ParamStore::from_tensors(vec![Tensor {
        name: "lora.a".to_string(),
        shape: vec![1],
        data: vec![tag],
    }])
}

/// Analytic-decay refresh runner over `registry` — the spin-up shared
/// by every suite (the stress tests drive it on the real clock). The
/// caller still `track_deployed`s at its own epoch.
pub fn analytic_runner(
    registry: &SharedRegistry,
    refitter: Arc<dyn Refitter>,
    tolerance: f64,
    time_scale: f64,
    metrics: Arc<Metrics>,
) -> RefreshRunner {
    runner_with_decay(
        registry,
        refitter,
        DecayModel::analytic(PcmModel::default()),
        tolerance,
        time_scale,
        metrics,
    )
}

/// [`analytic_runner`] generalised over the decay model, so a SimPool
/// can run on an arbitrary backend's drift physics (`serve::hal`).
pub fn runner_with_decay(
    registry: &SharedRegistry,
    refitter: Arc<dyn Refitter>,
    decay: DecayModel,
    tolerance: f64,
    time_scale: f64,
    metrics: Arc<Metrics>,
) -> RefreshRunner {
    let cfg = RefreshConfig::new(decay, refitter)
        .tolerance(tolerance)
        .time_scale(time_scale);
    RefreshRunner::new(
        cfg,
        registry.clone(),
        Arc::new(ParamStore::default()),
        metrics,
    )
}

/// First arrival gap on a log grid (1e2 .. ~9e15 ns) where the modeled
/// optimum differs from backend `from` AND the per-request saving
/// clears `need_ns` — how the rebalance suite and bench find a traffic
/// regime that provably opens the hysteresis gate, instead of
/// hard-coding magnitudes against the data-driven cost tables.
pub fn gap_shifting_from(
    profiles: &[BackendProfile],
    from: usize,
    tolerance: f64,
    need_ns: f64,
) -> Option<f64> {
    (0..280).map(|i| 10f64.powf(2.0 + i as f64 * 0.05)).find(|&gap| {
        let to = route_one(profiles, gap, tolerance);
        to != from
            && profiles[from].placement_cost(gap, tolerance)
                - profiles[to].placement_cost(gap, tolerance)
                > need_ns
    })
}

/// One simulated served batch: worker, pop instant, modeled completion,
/// fill, and the adapter version its registry snapshot pinned.
pub struct SimBatch {
    pub worker: usize,
    pub task: String,
    pub popped_at: Instant,
    pub done_at: Instant,
    pub fill: usize,
    pub version: u64,
}

/// One refresh hot-swap as the pool observed it.
pub struct SwapRecord {
    pub task: String,
    /// When the swap landed in the registry (post-refit).
    pub at: Instant,
    pub version: u64,
    /// The MODELED tolerance crossing of the deployment this swap
    /// replaced (pre-stagger): staggering must keep `at` near or before
    /// it — never sacrifice freshness for spread.
    pub modeled_due: Instant,
    /// First batch served at the new version (`None` until observed).
    pub first_serve_at: Option<Instant>,
}

impl SwapRecord {
    pub fn gap(&self) -> Option<Duration> {
        self.first_serve_at
            .map(|t| t.saturating_duration_since(self.at))
    }
}

struct SimWorker {
    sched: BatchScheduler,
    batcher: Batcher<Instant>,
    tasks: Vec<String>,
    /// The one task this shard is currently deferring for a pending
    /// hot-swap (mirrors the real worker loop: holds publish to the
    /// shared handle on transitions only, so the pool-wide count is a
    /// count of stalled shards).
    holding: Option<String>,
}

pub struct SimPoolBuilder {
    workers: usize,
    tasks: Vec<String>,
    max_batch: usize,
    max_wait: Duration,
    tolerance: f64,
    /// Pool-clock duration the modeled trigger is compressed to.
    trigger_in: Duration,
    coupling: Option<RefreshCoupling>,
    coord: Option<CoordConfig>,
    /// Virtual time one refit consumes (the modeled step budget).
    refit_advance: Duration,
    sched_cfg: SchedConfig,
    /// HAL backend whose drift model and scheduler adaptation the pool
    /// runs on; `None` keeps the historical analytic-PCM default.
    backend: Option<Arc<dyn Backend>>,
    /// ROUTED mode: ≥ 2 backends sharing the worker set behind a
    /// `Router` (contiguous even spans). Exclusive with `backend`.
    multi: Vec<Arc<dyn Backend>>,
    /// Cadenced adaptive rebalancer over the routed pool.
    rebalance: Option<RebalanceConfig>,
}

impl SimPoolBuilder {
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    pub fn tasks(mut self, names: &[&str]) -> Self {
        self.tasks = names.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n.max(1);
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    pub fn tolerance(mut self, tol: f64) -> Self {
        self.tolerance = tol;
        self
    }

    /// Compress the modeled drift trigger to `d` of pool clock (sets
    /// the refresh `time_scale` accordingly).
    pub fn trigger_in(mut self, d: Duration) -> Self {
        self.trigger_in = d;
        self
    }

    pub fn coupling(mut self, c: RefreshCoupling) -> Self {
        self.coupling = Some(c);
        self
    }

    /// Attach a pool coordinator (staggered triggers + adaptive
    /// window/hold). Without it each worker couples independently — the
    /// pre-coordinator baseline.
    pub fn coordinate(mut self, cfg: CoordConfig) -> Self {
        self.coord = Some(cfg);
        self
    }

    pub fn refit_advance(mut self, d: Duration) -> Self {
        self.refit_advance = d;
        self
    }

    /// Run the pool on an explicit `serve::hal` backend: its drift
    /// model drives the refresh policy and its `adapt_sched` shapes
    /// every worker's scheduler config. With `PcmPjrt::default()` this
    /// is behavior-identical to the builder default (pinned by the
    /// `hal_conformance` suite).
    pub fn backend(mut self, b: Arc<dyn Backend>) -> Self {
        self.backend = Some(b);
        self
    }

    /// Run a ROUTED heterogeneous pool: the worker set is split into
    /// contiguous spans (even split, remainder to the earlier spans),
    /// every push routes through a [`Router`], and each task's drift
    /// physics follow its routed substrate. Requires at least one
    /// worker per backend; exclusive with [`Self::backend`].
    pub fn backends(mut self, bs: &[Arc<dyn Backend>]) -> Self {
        self.multi = bs.to_vec();
        self
    }

    /// Attach the cadenced adaptive rebalancer to a routed pool. The
    /// sim ticks it once per round ([`SimPool::rebalance_tick`]) — the
    /// background `ahwa-rebalance` thread's timer, on the virtual
    /// clock. The hysteresis/cooldown gates still run on virtual time.
    pub fn rebalance(mut self, cfg: RebalanceConfig) -> Self {
        self.rebalance = Some(cfg);
        self
    }

    pub fn build(self) -> SimPool {
        let clock = Arc::new(VirtualClock::new());
        let registry = SharedRegistry::new();
        for t in &self.tasks {
            registry.deploy(t, adapter(1.0));
        }
        let metrics = Arc::new(Metrics::default());

        // refitter: bumps the adapter tag (so torn pairs are detectable)
        // and consumes `refit_advance` of virtual time — the measured
        // step budget the adaptive hold derives from
        let refitter: Arc<dyn Refitter> = {
            let (clock, advance) = (clock.clone(), self.refit_advance);
            Arc::new(FnRefitter(
                move |_: &str,
                      current: &ParamStore,
                      _: &ParamStore,
                      budget: usize|
                      -> anyhow::Result<Refit> {
                    clock.advance(advance);
                    Ok(Refit {
                        params: adapter(current.tensors[0].data[0] + 1.0),
                        steps: budget,
                    })
                },
            ))
        };

        let routed = !self.multi.is_empty();
        assert!(
            self.backend.is_none() || !routed,
            "single-backend mode and routed mode are exclusive"
        );
        let decay = if routed {
            self.multi[0].drift_model().unwrap_or_else(drift_free)
        } else {
            match &self.backend {
                Some(b) => b.drift_model().unwrap_or_else(drift_free),
                None => DecayModel::analytic(PcmModel::default()),
            }
        };
        // in routed mode the clock compression follows the FASTEST
        // drifting substrate (the one the trigger_in deadline is about)
        let age = if routed {
            self.multi
                .iter()
                .map(|b| {
                    b.drift_model()
                        .unwrap_or_else(drift_free)
                        .trigger_age(self.tolerance)
                })
                .filter(|a| a.is_finite())
                .fold(f64::INFINITY, f64::min)
        } else {
            decay.trigger_age(self.tolerance)
        };
        // A drift-free backend never triggers: leave the clock unscaled
        // instead of dividing infinity.
        let time_scale = if age.is_finite() {
            age / self.trigger_in.as_secs_f64().max(1e-12)
        } else {
            1.0
        };
        let mut runner = runner_with_decay(
            &registry,
            refitter,
            decay,
            self.tolerance,
            time_scale,
            metrics.clone(),
        )
        .with_clock(clock.clone() as Arc<dyn Clock>);
        runner.track_deployed(clock.now());
        let handle = runner.policy().handle();
        let coordinator = self.coord.map(|cfg| {
            let c = Arc::new(RefreshCoordinator::new(cfg, handle.clone(), metrics.clone()));
            runner.set_coordinator(c.clone());
            c
        });

        // routed mode: profiles + contiguous even worker spans behind a
        // Router; every task is placed up front (route-on-first-use on
        // whatever evidence exists — none yet, so costed at saturation)
        // and its drift physics follow the routed substrate
        let router: Option<Arc<Router>> = if routed {
            let k = self.multi.len();
            assert!(
                self.workers >= k,
                "routed pool needs at least one worker per backend ({} workers, {k} backends)",
                self.workers
            );
            let profiles: Vec<BackendProfile> = self
                .multi
                .iter()
                .map(|b| BackendProfile::of(b.as_ref(), &self.sched_cfg, self.max_batch))
                .collect();
            let (base, rem) = (self.workers / k, self.workers % k);
            let mut ranges = Vec::with_capacity(k);
            let mut start = 0;
            for i in 0..k {
                let size = base + usize::from(i < rem);
                ranges.push((start, start + size));
                start += size;
            }
            Some(Arc::new(Router::new(
                profiles,
                ranges,
                self.tolerance,
                BTreeMap::new(),
                BTreeMap::new(),
                clock.clone() as Arc<dyn Clock>,
            )))
        } else {
            None
        };
        if let Some(rt) = &router {
            for t in &self.tasks {
                let b = rt.backend_of(t);
                runner
                    .policy_mut()
                    .set_task_decay(t, self.multi[b].drift_model().unwrap_or_else(drift_free));
            }
        }

        // one scheduler + batcher per worker; in routed mode each
        // worker batches on ITS span's backend-adapted layer model
        let mut workers = Vec::with_capacity(self.workers);
        let mut task_worker = BTreeMap::new();
        for w in 0..self.workers {
            let mut scfg = if let Some(rt) = &router {
                let bi = rt
                    .ranges()
                    .iter()
                    .position(|&(s, e)| w >= s && w < e)
                    .expect("every worker belongs to a span");
                self.multi[bi].adapt_sched(self.sched_cfg)
            } else {
                match &self.backend {
                    Some(b) => b.adapt_sched(self.sched_cfg),
                    None => self.sched_cfg,
                }
            };
            if let Some(c) = self.coupling {
                scfg = scfg.coupling(c);
            }
            workers.push(SimWorker {
                sched: BatchScheduler::new(scfg, self.max_batch, self.max_wait)
                    .with_refresh(handle.clone()),
                batcher: Batcher::with_clock(
                    self.max_batch,
                    self.max_wait,
                    clock.clone() as Arc<dyn Clock>,
                ),
                tasks: Vec::new(),
                holding: None,
            });
        }
        // task→worker: routed pools follow the router's span hash,
        // homogeneous pools keep the historical round-robin
        for (i, t) in self.tasks.iter().enumerate() {
            let w = match &router {
                Some(rt) => rt.worker_of(t),
                None => i % workers.len(),
            };
            workers[w].tasks.push(t.clone());
            task_worker.insert(t.clone(), w);
        }
        let modeled_due: BTreeMap<String, Instant> = self
            .tasks
            .iter()
            .filter_map(|t| handle.trigger_at(t).map(|at| (t.clone(), at)))
            .collect();

        let runner = Arc::new(Mutex::new(runner));
        let rebalancer = match (&router, self.rebalance) {
            (Some(rt), Some(rcfg)) => Some(
                RebalanceRunner::new(rcfg, rt.clone(), self.multi.clone())
                    .with_refresh(handle.clone(), runner.clone())
                    .with_metrics(metrics.clone()),
            ),
            (None, Some(_)) => panic!("rebalance needs a routed (multi-backend) SimPool"),
            _ => None,
        };

        SimPool {
            clock,
            registry,
            runner,
            coordinator,
            handle,
            metrics,
            router,
            rebalancer,
            tolerance: self.tolerance,
            workers,
            task_worker,
            modeled_due,
            batches: Vec::new(),
            swaps: Vec::new(),
            moves: Vec::new(),
            modeled_cost_ns: Vec::new(),
            drains: 0,
            holds: 0,
            max_holding: 0,
            lat_ns: Vec::new(),
        }
    }
}

/// N simulated workers + refresh runner (+ optional coordinator) on one
/// shared `VirtualClock`. See the module docs.
pub struct SimPool {
    pub clock: Arc<VirtualClock>,
    pub registry: SharedRegistry,
    pub runner: Arc<Mutex<RefreshRunner>>,
    pub coordinator: Option<Arc<RefreshCoordinator>>,
    pub handle: RefreshHandle,
    pub metrics: Arc<Metrics>,
    /// Routed mode only: the task→backend router behind the spans.
    pub router: Option<Arc<Router>>,
    /// Routed mode + [`SimPoolBuilder::rebalance`] only.
    rebalancer: Option<RebalanceRunner>,
    /// The pool-wide drift tolerance (routing default).
    tolerance: f64,
    workers: Vec<SimWorker>,
    task_worker: BTreeMap<String, usize>,
    /// Modeled (pre-stagger) tolerance crossing of each task's CURRENT
    /// deployment, refreshed after every runner tick.
    modeled_due: BTreeMap<String, Instant>,
    pub batches: Vec<SimBatch>,
    pub swaps: Vec<SwapRecord>,
    /// Applied rebalance moves, stamped with their handoff instant.
    pub moves: Vec<(Instant, PlannedMove)>,
    /// Routed mode: modeled per-request placement cost of the routing
    /// in effect at each push (service + tolerance maintenance on the
    /// request's CURRENT backend) — the adaptive-vs-sticky comparison
    /// statistic the rebalance suite and bench aggregate.
    pub modeled_cost_ns: Vec<f64>,
    /// Pressure-shaped (`Decision::Drain`) closes observed.
    pub drains: usize,
    /// `Decision::Hold` deferrals observed.
    pub holds: usize,
    /// Most tasks simultaneously in a hold across the pool, observed at
    /// every scheduling decision (holding state only changes at
    /// decisions, so this is exact on the virtual clock).
    pub max_holding: usize,
    /// Per-request modeled latency (enqueue → modeled completion), ns.
    pub lat_ns: Vec<f64>,
}

impl SimPool {
    pub fn builder() -> SimPoolBuilder {
        SimPoolBuilder {
            workers: 1,
            tasks: vec!["task".to_string()],
            max_batch: MAX_BATCH,
            max_wait: Duration::from_millis(5),
            tolerance: 0.05,
            trigger_in: Duration::from_millis(100),
            coupling: None,
            coord: None,
            refit_advance: Duration::ZERO,
            sched_cfg: SchedConfig::for_layer(128, 128, 8).seq(320),
            backend: None,
            multi: Vec::new(),
            rebalance: None,
        }
    }

    pub fn now(&self) -> Instant {
        self.clock.now()
    }

    pub fn advance(&self, d: Duration) {
        self.clock.advance(d);
    }

    /// Modeled batch latency of worker 0's cost model (all workers
    /// share the hardware config, so this is the pool-wide pacing
    /// reference).
    pub fn modeled_batch_ns(&self, fill: usize) -> f64 {
        self.workers[0].sched.modeled_batch_ns(fill)
    }

    /// Enqueue one request for `task` at the current instant: routed
    /// pools consult the router (feeding its arrival EWMA and logging
    /// the modeled placement cost of the routing in effect),
    /// homogeneous pools use the fixed task→worker pin. Either way the
    /// chosen worker's arrival-rate estimator sees the request.
    pub fn push(&mut self, task: &str) {
        let now = self.clock.now();
        let w = match &self.router {
            Some(rt) => {
                let w = rt.worker_for(task);
                let b = rt.backend_of(task);
                let gap = rt.arrival_ewma_ns(task).unwrap_or(f64::INFINITY);
                self.modeled_cost_ns
                    .push(rt.profiles()[b].placement_cost(gap, self.tolerance));
                self.task_worker.insert(task.to_string(), w);
                w
            }
            None => *self.task_worker.get(task).expect("deployed task"),
        };
        self.workers[w].sched.observe_arrival(task, now);
        self.workers[w].batcher.push(task, now);
    }

    /// One refresh-runner evaluation at the current instant, recording
    /// every hot-swap against the modeled due time it replaced.
    pub fn tick(&mut self) {
        let events = self
            .runner
            .lock()
            .expect("refresh runner")
            .tick(self.clock.now());
        for ev in events {
            let modeled_due = self.modeled_due.get(&ev.task).copied().unwrap_or(ev.at);
            self.swaps.push(SwapRecord {
                task: ev.task.clone(),
                at: ev.at,
                version: ev.version,
                modeled_due,
                first_serve_at: None,
            });
        }
        // re-read the (re-anchored) modeled crossings for the next cycle
        for (task, due) in self.modeled_due.iter_mut() {
            if let Some(at) = self.handle.trigger_at(task) {
                *due = at;
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.workers.iter().map(|w| w.batcher.pending()).sum()
    }

    /// Run every worker's pop loop until no worker can make progress,
    /// recording batches, Drain/Hold activity, hold concurrency, and
    /// first-serve instants for pending swaps.
    pub fn drain(&mut self) {
        let mut progressed = true;
        while progressed {
            progressed = false;
            for w in 0..self.workers.len() {
                let now = self.clock.now();
                let decision = self.workers[w].sched.pick(&self.workers[w].batcher, now);
                let (task, fill, drained) = match decision {
                    Decision::Close { task, fill } => (task, fill, false),
                    Decision::Drain { task, fill } => (task, fill, true),
                    Decision::Hold { task, .. } => {
                        self.holds += 1;
                        // transition-only, one flagged task per shard —
                        // exactly the real worker loop's discipline
                        if self.workers[w].holding.as_deref() != Some(task.as_str()) {
                            if let Some(prev) = self.workers[w].holding.take() {
                                self.handle.set_holding(&prev, false);
                            }
                            let n = self.handle.set_holding(&task, true);
                            self.max_holding = self.max_holding.max(n);
                            self.metrics
                                .concurrent_holds_peak
                                .fetch_max(n as u64, Ordering::Relaxed);
                            self.workers[w].holding = Some(task);
                        }
                        continue;
                    }
                    Decision::Wait { .. } | Decision::Idle => continue,
                };
                if drained {
                    self.drains += 1;
                }
                if self.workers[w].holding.as_deref() == Some(task.as_str()) {
                    self.handle.set_holding(&task, false);
                    self.workers[w].holding = None;
                }
                let reqs = self.workers[w]
                    .batcher
                    .pop_task(&task, fill)
                    .expect("ready batch");
                assert_eq!(reqs.len(), fill, "pop honours the decided fill");
                // migration freeze lifts at queue-empty — exactly the
                // real worker loop's discipline (serve::pool)
                if self.workers[w].batcher.pending_for(&task) == 0
                    && self.handle.is_migrating(&task)
                {
                    self.handle.set_migrating(&task, false);
                }
                let (_, version) = self.registry.snapshot(&task).expect("deployed");
                let done_at = now + self.workers[w].sched.modeled_batch(fill);
                for enqueued in &reqs {
                    self.lat_ns
                        .push(done_at.saturating_duration_since(*enqueued).as_nanos() as f64);
                }
                // first batch at a refresh-installed version: record the
                // swap → serve handoff and feed the coordinator's
                // adaptive window, exactly like the real pool worker
                if let Some(rec) = self.swaps.iter_mut().find(|r| {
                    r.task == task && r.version == version && r.first_serve_at.is_none()
                }) {
                    rec.first_serve_at = Some(now);
                    let gap = now.saturating_duration_since(rec.at);
                    self.metrics
                        .swap_gap_ns
                        .fetch_max(gap.as_nanos() as u64, Ordering::Relaxed);
                    self.handle.observe_swap_gap(&task, gap);
                }
                self.batches.push(SimBatch {
                    worker: w,
                    task,
                    popped_at: now,
                    done_at,
                    fill,
                    version,
                });
                progressed = true;
            }
        }
    }

    /// One cadenced rebalance pass at the current instant (the sim's
    /// analogue of the background `ahwa-rebalance` thread's timer; a
    /// no-op without [`SimPoolBuilder::rebalance`]): the runner
    /// retires idle tasks, plans under the hysteresis gate, and runs
    /// the freeze → carry → flip handoff per approved move. The sim
    /// then hands each moved task's queued requests to the destination
    /// span's batcher with their enqueue stamps intact and lifts the
    /// migration freeze — the batch-boundary queue-empty handoff,
    /// compressed to one virtual-clock instant.
    pub fn rebalance_tick(&mut self) -> Vec<PlannedMove> {
        if self.rebalancer.is_none() {
            return Vec::new();
        }
        let now = self.clock.now();
        let moves = self.rebalancer.as_ref().expect("checked above").tick(now);
        let router = self.router.as_ref().expect("routed pool").clone();
        for mv in &moves {
            let dest = router.worker_of(&mv.task);
            if let Some(src) = self.task_worker.insert(mv.task.clone(), dest) {
                if src != dest {
                    if let Some(items) = self.workers[src].batcher.take_task(&mv.task) {
                        self.workers[dest].batcher.adopt(&mv.task, items);
                    }
                    if self.workers[src].holding.as_deref() == Some(mv.task.as_str()) {
                        self.handle.set_holding(&mv.task, false);
                        self.workers[src].holding = None;
                    }
                    self.workers[src].tasks.retain(|t| t != &mv.task);
                    if !self.workers[dest].tasks.contains(&mv.task) {
                        self.workers[dest].tasks.push(mv.task.clone());
                    }
                }
            }
            // the handoff emptied the old span at this same instant,
            // so the freeze lifts at once (the real worker clears the
            // flag at queue-empty)
            if self.handle.is_migrating(&mv.task) {
                self.handle.set_migrating(&mv.task, false);
            }
            self.moves.push((now, mv.clone()));
        }
        moves
    }

    /// Drive `rounds` arrival rounds: each round advances the clock by
    /// `ia`, enqueues one request per task, drains every worker, then
    /// runs one refresh tick (the background worker's check cadence)
    /// and one rebalance tick (a no-op unless the pool is routed with
    /// a rebalance config). Draining BEFORE the ticks means the first
    /// serve of a refreshed version lands one round after its swap —
    /// a stable, non-zero swap gap the adaptive window must learn —
    /// and that a migration's queue handoff happens at a batch
    /// boundary, never mid-drain.
    pub fn run_rounds(&mut self, rounds: usize, ia: Duration) {
        let tasks: Vec<String> = self.task_worker.keys().cloned().collect();
        for _ in 0..rounds {
            self.advance(ia);
            for t in &tasks {
                self.push(t);
            }
            self.drain();
            self.tick();
            self.rebalance_tick();
        }
    }

    /// Flush the tail past any deadline/hold in `step`-sized advances,
    /// refresh still ticking on the same drain-then-tick cadence as
    /// [`Self::run_rounds`] (so swap gaps observed during the flush
    /// stay consistent with the in-stream ones).
    pub fn flush(&mut self, step: Duration) {
        let step = step.max(Duration::from_nanos(1));
        let mut rounds = 0;
        while self.pending() > 0 {
            self.advance(step);
            self.drain();
            self.tick();
            rounds += 1;
            assert!(rounds < 8192, "tail must drain");
        }
    }

    pub fn served(&self) -> usize {
        self.batches.iter().map(|b| b.fill).sum()
    }

    /// Swap records of `task`, in order.
    pub fn swaps_for(&self, task: &str) -> Vec<&SwapRecord> {
        self.swaps.iter().filter(|r| r.task == task).collect()
    }

    /// Mean observed swap → first-serve gap for `task` (the "true" gap
    /// the adaptive window must converge towards).
    pub fn mean_gap(&self, task: &str) -> Option<Duration> {
        let gaps: Vec<Duration> = self
            .swaps_for(task)
            .iter()
            .filter_map(|r| r.gap())
            .collect();
        if gaps.is_empty() {
            return None;
        }
        Some(gaps.iter().sum::<Duration>() / gaps.len() as u32)
    }
}

// ---------------------------------------------------------------------------
// Shared multi-worker geometry (coord_conformance + the bench)
// ---------------------------------------------------------------------------

/// Scale-free geometry for the multi-worker coordination scenarios:
/// every duration is expressed in units of the modeled single-request
/// batch latency (`ia` = 2× that), so arrivals are always slower than
/// service — the modeled-optimal fill is 1, queues never back up, and
/// the post-swap first serve lands exactly one arrival after each
/// hot-swap on ANY hardware model. That stable one-arrival swap gap is
/// what the coordinator's adaptive window must learn.
///
/// Used by `tests/coord_conformance.rs` and
/// `benches/serving_refresh_sched.rs`, so suite and bench cannot
/// diverge.
#[derive(Clone, Copy, Debug)]
pub struct CoordGeom {
    /// Arrival cadence per task; also the refresh-runner check cadence.
    pub ia: Duration,
    /// Virtual time one refit consumes (the modeled step budget).
    pub refit: Duration,
    /// Pool-clock compression of the modeled drift trigger (the cycle
    /// length).
    pub trigger_in: Duration,
    pub max_wait: Duration,
    /// Coordinator re-phase budget.
    pub slack: Duration,
    /// The FIXED coupling window (what the uncoordinated baseline keeps
    /// forever): 20 arrivals — provably > 2× the one-arrival true gap.
    pub fixed_window: Duration,
    /// The fixed hold bound (generous; the adaptive one replaces it).
    pub fixed_hold: Duration,
    /// First-cycle stagger spacing fallback.
    pub fallback_hold: Duration,
}

impl CoordGeom {
    pub fn derive() -> CoordGeom {
        let probe = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            MAX_BATCH,
            Duration::from_millis(5),
        );
        let ia = Duration::from_nanos((probe.modeled_batch_ns(1) * 2.0).round() as u64)
            .max(Duration::from_micros(1));
        CoordGeom {
            ia,
            refit: ia * 10,
            trigger_in: ia * 600,
            max_wait: ia * 50,
            slack: ia * 400,
            fixed_window: ia * 20,
            fixed_hold: ia * 200,
            fallback_hold: ia * 50,
        }
    }

    /// The fixed coupling both modes run with (the coordinator adapts
    /// window/hold on top of it; the baseline keeps it as-is).
    pub fn coupling(&self) -> RefreshCoupling {
        RefreshCoupling::default()
            .window(self.fixed_window)
            .hold(self.fixed_hold)
    }

    /// Coordinator config at concurrency bound `k`.
    pub fn coord(&self, k: usize) -> CoordConfig {
        let min_window = Duration::from_nanos(((self.ia.as_nanos() / 4).max(1)) as u64);
        CoordConfig::default()
            .max_concurrent_holds(k)
            .slack(self.slack)
            .fallback_window(self.fixed_window)
            .fallback_hold(self.fallback_hold)
            .hold_gain(3.0)
            .hold_bounds(self.ia, Duration::from_secs(3600))
            .window_bounds(min_window, Duration::from_secs(3600))
    }

    /// A `workers`-wide pool over `tasks` sharing one tolerance, with
    /// (`coordinated`) or without the pool coordinator at bound `k`.
    pub fn pool(&self, workers: usize, tasks: &[&str], coordinated: bool, k: usize) -> SimPool {
        let mut b = SimPool::builder()
            .workers(workers)
            .tasks(tasks)
            .max_wait(self.max_wait)
            .tolerance(0.05)
            .trigger_in(self.trigger_in)
            .refit_advance(self.refit)
            .coupling(self.coupling());
        if coordinated {
            b = b.coordinate(self.coord(k));
        }
        b.build()
    }

    /// Freshness bound: a swap may land at most one check interval plus
    /// `refits` serialized refit budgets after the modeled crossing,
    /// with one extra arrival of cushion.
    pub fn margin(&self, refits: u32) -> Duration {
        self.ia + self.refit * refits + self.ia
    }
}

// ---------------------------------------------------------------------------
// The single-worker coupled-vs-uncoupled scenario (refresh_sched_e2e +
// the serving_refresh_sched bench), expressed on the SimPool harness.
// ---------------------------------------------------------------------------

pub struct SimRun {
    pub batches: Vec<SimBatch>,
    /// Per-request modeled latency (enqueue → modeled completion), ns.
    pub lat_ns: Vec<f64>,
    /// Modeled tolerance-crossing instant of the initial deployment.
    pub trigger_at: Instant,
    /// When the refresh hot-swap actually landed in the registry.
    pub swap_at: Instant,
    pub swap_version: u64,
    /// Pressure-shaped (`Decision::Drain`) closes observed.
    pub drains: usize,
    /// `Decision::Hold` deferrals observed.
    pub holds: usize,
}

impl SimRun {
    pub fn served(&self) -> usize {
        self.batches.iter().map(|b| b.fill).sum()
    }

    /// Requests that completed after the modeled trigger while still on
    /// the pre-refresh adapter version — the stale-service count the
    /// coupling must drive to zero.
    pub fn stale_after_trigger(&self) -> usize {
        self.batches
            .iter()
            .filter(|b| b.version < self.swap_version && b.done_at > self.trigger_at)
            .map(|b| b.fill)
            .sum()
    }

    /// Batches whose modeled service interval contains the swap.
    pub fn spanning_batches(&self) -> usize {
        self.batches
            .iter()
            .filter(|b| b.popped_at < self.swap_at && b.done_at > self.swap_at)
            .count()
    }

    /// First batch popped at or after the swap instant.
    pub fn first_post_swap(&self) -> Option<&SimBatch> {
        self.batches.iter().find(|b| b.popped_at >= self.swap_at)
    }

    /// Registry-swap → first-serve gap (zero when nothing served after
    /// the swap).
    pub fn swap_gap(&self) -> Duration {
        self.first_post_swap()
            .map(|b| b.popped_at.saturating_duration_since(self.swap_at))
            .unwrap_or_default()
    }
}

/// Drive the full single-worker cycle on the virtual clock. `coupled`
/// switches the scheduler's refresh coupling on; the refresh runner
/// itself runs identically in both modes.
pub fn simulate(coupled: bool, n_requests: usize) -> SimRun {
    let max_wait = Duration::from_millis(5);
    // derive pacing from an uncoupled probe of the same hardware model
    let probe = BatchScheduler::new(
        SchedConfig::for_layer(128, 128, 8).seq(320),
        MAX_BATCH,
        max_wait,
    );
    let per = |b: usize| probe.modeled_batch_ns(b) / b as f64;
    // arrivals twice as fast as a full batch's per-request service
    // time: no fill sustains the rate, so the modeled-optimal fill is
    // MAX_BATCH and the queue never goes idle mid-run
    let ia = Duration::from_nanos((per(MAX_BATCH) / 2.0).round() as u64);

    let mut b = SimPool::builder()
        .workers(1)
        .tasks(&["task"])
        .max_batch(MAX_BATCH)
        .max_wait(max_wait)
        .tolerance(0.05);
    if coupled {
        b = b.coupling(
            RefreshCoupling::default()
                .window(ia * 64)
                .hold(max_wait)
                .post_swap_window(ia * 64),
        );
    }
    // keep the modeled timescale 1:1 (trigger compressed to itself) and
    // fast-forward instead, so the trigger lands mid-stream — exactly
    // the historical single-worker harness geometry
    let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
    let mut pool = b.trigger_in(Duration::from_secs_f64(age)).build();
    let half_span = ia * (n_requests as u32 / 2);
    pool.advance(Duration::from_secs_f64(age) - half_span);
    let trigger_at = pool.handle.trigger_at("task").expect("modeled trigger");

    for _ in 0..n_requests {
        pool.advance(ia);
        // the background refresh worker's check cadence: every arrival
        pool.tick();
        pool.push("task");
        pool.drain();
    }
    // flush the tail past any deadline/hold, refresh still ticking
    let mut rounds = 0;
    while pool.pending() > 0 {
        pool.advance(max_wait);
        pool.tick();
        pool.drain();
        rounds += 1;
        assert!(rounds < 64, "tail must drain");
    }
    assert_eq!(pool.lat_ns.len(), n_requests, "every request served");

    let (swap_at, swap_version) = pool
        .swaps
        .first()
        .map(|r| (r.at, r.version))
        .unwrap_or((trigger_at, 1));
    SimRun {
        batches: std::mem::take(&mut pool.batches),
        lat_ns: std::mem::take(&mut pool.lat_ns),
        trigger_at,
        swap_at,
        swap_version,
        drains: pool.drains,
        holds: pool.holds,
    }
}

// ---------------------------------------------------------------------------
// Continuous-batching decode sim (decode_conformance + serving_decode)
// ---------------------------------------------------------------------------

/// Stop token the decode sim's synthetic model emits to end a sequence
/// (kept clear of PAD so the engine's PAD hygiene stays observable).
pub const DECODE_STOP: i32 = 1;

/// Filler content token for synthetic prompts and generated bodies.
pub const DECODE_CONTENT: i32 = 3;

/// Vocabulary of the synthetic decode model.
pub const DECODE_VOCAB: usize = 8;

/// One request of a decode arrival trace: offset from the drive start,
/// prompt, and the number of content tokens before the stop token.
#[derive(Clone, Debug)]
pub struct DecodeArrival {
    pub at: Duration,
    pub prompt: Vec<i32>,
    pub gen_len: usize,
}

/// Deterministic arrival trace: request `i` arrives at `i * gap` with a
/// short varied prompt and a generation length cycling over `gen_lens`
/// — the SAME trace feeds the continuous and the static run, so the
/// occupancy comparison is apples-to-apples.
pub fn decode_trace(n: usize, gap: Duration, gen_lens: &[usize]) -> Vec<DecodeArrival> {
    assert!(!gen_lens.is_empty());
    (0..n)
        .map(|i| DecodeArrival {
            at: gap * i as u32,
            prompt: vec![DECODE_CONTENT; 2 + i % 3],
            gen_len: gen_lens[i % gen_lens.len()],
        })
        .collect()
}

/// One decode step as the sim ran it.
pub struct DecodeStepRecord {
    /// Step-boundary instant (before the step's modeled latency).
    pub at: Instant,
    /// Live sequences stepped.
    pub fill: usize,
    /// Adapter version the step's fresh snapshot pinned.
    pub version: u64,
}

/// One completed generation with its timing and version span.
pub struct SimGeneration {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Adapter versions of the first and last step; unequal exactly when
    /// the sequence crossed a drain-free mid-sequence hot-swap.
    pub first_version: u64,
    pub last_version: u64,
    pub enqueued_at: Instant,
    pub first_token_at: Instant,
    pub done_at: Instant,
}

struct DecodeSeq {
    id: u64,
    prompt_len: usize,
    gen_len: usize,
    enqueued_at: Instant,
    tokens: Vec<i32>,
    first_version: Option<u64>,
    first_token_at: Option<Instant>,
    last_token_at: Option<Instant>,
}

/// Verdict of one [`SimDecode::step`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeOutcome {
    /// One step-batch ran (modeled latency consumed on the clock).
    Progressed,
    /// The step-boundary refresh gate deferred the step.
    Held(Instant),
    /// Nothing queued, nothing in flight.
    Idle,
}

/// One worker's continuous-batching decode lane, mirrored on the
/// virtual clock: the SAME join / fresh-snapshot / [`step_gate`] /
/// step / retire discipline as `serve::pool`'s decode pass, with the
/// forward replaced by a synthetic model (every live row continues with
/// [`DECODE_CONTENT`] until its target length, then [`DECODE_STOP`])
/// and the step latency by the scheduler's committed-sweep lookup —
/// the same [`BatchScheduler::modeled_batch`] table the real worker's
/// re-balance consults.
///
/// `continuous: false` degrades the lane to the static baseline: join
/// only when the engine is empty, i.e. classic run-the-batch-to-
/// completion decoding over the identical arrival trace.
pub struct SimDecode {
    pub clock: Arc<VirtualClock>,
    pub metrics: Arc<Metrics>,
    pub engine: StepEngine,
    sched: BatchScheduler,
    continuous: bool,
    /// Hold budget the step gate falls back to when the coordinator has
    /// not adapted one.
    pub fallback_hold: Duration,
    queue: VecDeque<(u64, Vec<i32>, usize, Instant)>,
    rows: Vec<Option<DecodeSeq>>,
    next_id: u64,
    held_since: Option<Instant>,
    last_version: Option<u64>,
    pub steps: Vec<DecodeStepRecord>,
    pub finished: Vec<SimGeneration>,
    /// Steps that ran against a stale-past-trigger snapshot (hold
    /// budget exhausted) — the count the conformance suite pins at 0.
    pub stale_steps: usize,
    /// Version changes observed under carried-over live sequences.
    pub mid_seq_swaps: u64,
    /// Per-token inter-token gaps (ns), all sequences pooled.
    pub itl_ns: Vec<f64>,
    /// Per-sequence time-to-first-token (ns).
    pub ttft_ns: Vec<f64>,
}

impl SimDecode {
    pub fn new(
        clock: Arc<VirtualClock>,
        metrics: Arc<Metrics>,
        b: usize,
        s: usize,
        continuous: bool,
    ) -> SimDecode {
        SimDecode {
            clock,
            metrics,
            engine: StepEngine::new(b, s, DECODE_VOCAB),
            sched: BatchScheduler::new(
                SchedConfig::for_layer(128, 128, 8).seq(320),
                b,
                Duration::from_millis(5),
            ),
            continuous,
            fallback_hold: Duration::from_millis(5),
            queue: VecDeque::new(),
            rows: (0..b).map(|_| None).collect(),
            next_id: 0,
            held_since: None,
            last_version: None,
            steps: Vec::new(),
            finished: Vec::new(),
            stale_steps: 0,
            mid_seq_swaps: 0,
            itl_ns: Vec::new(),
            ttft_ns: Vec::new(),
        }
    }

    /// Modeled latency of one step at `fill` — a lookup into the
    /// scheduler's committed sweep, exactly the worker's re-balance.
    pub fn step_time(&self, fill: usize) -> Duration {
        self.sched.modeled_batch(fill)
    }

    pub fn busy(&self) -> bool {
        self.engine.occupied() > 0 || !self.queue.is_empty()
    }

    pub fn enqueue(&mut self, prompt: Vec<i32>, gen_len: usize) -> u64 {
        // the real path bounces empty prompts at admission
        // (Client::generate / accept_gen); the sim requires the same
        assert!(!prompt.is_empty(), "sim prompts must be non-empty");
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, prompt, gen_len, self.clock.now()));
        id
    }

    /// One step boundary: admit joiners (continuous) or a whole batch
    /// (static, engine empty only), take a FRESH registry snapshot,
    /// consult the refresh gate, then run one step whose modeled
    /// latency advances the shared clock.
    pub fn step(
        &mut self,
        registry: &SharedRegistry,
        handle: Option<&RefreshHandle>,
        task: &str,
    ) -> DecodeOutcome {
        let carried = self.engine.live() > 0;
        if self.continuous || self.engine.occupied() == 0 {
            while self.engine.has_room() {
                let Some((id, prompt, gen_len, at)) = self.queue.pop_front() else {
                    break;
                };
                // budget = content tokens + the stop token
                let row = self
                    .engine
                    .admit(id, &prompt, gen_len + 1, &[DECODE_STOP])
                    .expect("has_room guaranteed a free row");
                self.rows[row] = Some(DecodeSeq {
                    id,
                    prompt_len: prompt.len().min(self.engine.seq() - 1),
                    gen_len,
                    enqueued_at: at,
                    tokens: Vec::new(),
                    first_version: None,
                    first_token_at: None,
                    last_token_at: None,
                });
            }
        }
        let fill = self.engine.live();
        if fill == 0 {
            return DecodeOutcome::Idle;
        }
        let now = self.clock.now();
        let (_, version) = registry.snapshot(task).expect("deployed task");
        if let Some(h) = handle {
            match step_gate(
                h.view(task),
                version,
                now,
                self.fallback_hold,
                &mut self.held_since,
            ) {
                StepGate::Hold { until } => return DecodeOutcome::Held(until),
                StepGate::Go => {}
            }
            if h.is_stale(task, version, now) {
                self.stale_steps += 1;
            }
        }
        if carried && self.last_version.map_or(false, |v| v != version) {
            self.mid_seq_swaps += 1;
            self.metrics.mid_seq_swaps.fetch_add(1, Ordering::Relaxed);
        }
        self.last_version = Some(version);

        // synthetic model: each live row's argmax is the next content
        // token, or the stop token once its target length is reached
        let (b, s, vocab) = (
            self.engine.capacity(),
            self.engine.seq(),
            self.engine.vocab(),
        );
        let mut logits = vec![0f32; b * s * vocab];
        for (row, seq) in self.rows.iter().enumerate() {
            let Some(seq) = seq.as_ref() else { continue };
            let len = seq.prompt_len + seq.tokens.len();
            let tok = if seq.tokens.len() >= seq.gen_len {
                DECODE_STOP
            } else {
                DECODE_CONTENT
            };
            logits[(row * s + len - 1) * vocab + tok as usize] = 1.0;
        }

        let modeled = self.step_time(fill);
        self.clock.advance(modeled);
        let after = self.clock.now();
        let emits = self.engine.apply_logits(&logits);
        self.metrics
            .record_decode_step(fill, b, emits.len(), Some(modeled));
        self.steps.push(DecodeStepRecord { at: now, fill, version });
        for e in emits {
            let seq = self.rows[e.row].as_mut().expect("stepped row is tracked");
            if e.index == 0 {
                let d = after.saturating_duration_since(seq.enqueued_at);
                self.ttft_ns.push(d.as_nanos() as f64);
                self.metrics.record_ttft(d);
                seq.first_token_at = Some(after);
                seq.first_version = Some(version);
            } else if let Some(prev) = seq.last_token_at {
                let d = after.saturating_duration_since(prev);
                self.itl_ns.push(d.as_nanos() as f64);
                self.metrics.record_intertoken(d);
            }
            seq.last_token_at = Some(after);
            seq.tokens.push(e.token);
            if e.finished {
                let seq = self.rows[e.row].take().expect("finished row is tracked");
                self.engine.release(e.row);
                self.metrics.generations.fetch_add(1, Ordering::Relaxed);
                self.finished.push(SimGeneration {
                    id: seq.id,
                    tokens: seq.tokens,
                    first_version: seq.first_version.unwrap_or(version),
                    last_version: version,
                    enqueued_at: seq.enqueued_at,
                    first_token_at: seq.first_token_at.unwrap_or(after),
                    done_at: after,
                });
            }
        }
        DecodeOutcome::Progressed
    }

    /// Mean step-batch occupancy: live rows per step over capacity.
    pub fn occupancy(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|st| st.fill as f64).sum::<f64>()
            / (self.steps.len() * self.engine.capacity()) as f64
    }

    /// Modeled makespan: drive start → last retirement.
    pub fn makespan(&self, start: Instant) -> Duration {
        self.finished
            .iter()
            .map(|g| g.done_at.saturating_duration_since(start))
            .max()
            .unwrap_or_default()
    }
}

/// Registry + analytic refresh runner spin-up for the decode scenarios
/// (the decode analogue of [`SimPoolBuilder::build`]'s refresh side):
/// every task deploys `adapter(1.0)` at version 1, the modeled drift
/// trigger is compressed to `trigger_in` of pool clock, and each refit
/// bumps the tag and consumes `refit_advance` of virtual time.
pub struct SimRefresh {
    pub clock: Arc<VirtualClock>,
    pub registry: SharedRegistry,
    pub runner: RefreshRunner,
    pub handle: RefreshHandle,
    pub metrics: Arc<Metrics>,
}

pub fn decode_refresh(
    tasks: &[&str],
    trigger_in: Duration,
    refit_advance: Duration,
    coord: Option<CoordConfig>,
) -> SimRefresh {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    for t in tasks {
        registry.deploy(t, adapter(1.0));
    }
    let metrics = Arc::new(Metrics::default());
    let refitter: Arc<dyn Refitter> = {
        let (clock, advance) = (clock.clone(), refit_advance);
        Arc::new(FnRefitter(
            move |_: &str,
                  current: &ParamStore,
                  _: &ParamStore,
                  budget: usize|
                  -> anyhow::Result<Refit> {
                clock.advance(advance);
                Ok(Refit {
                    params: adapter(current.tensors[0].data[0] + 1.0),
                    steps: budget,
                })
            },
        ))
    };
    let tolerance = 0.05;
    let age = DecayModel::analytic(PcmModel::default()).trigger_age(tolerance);
    let time_scale = age / trigger_in.as_secs_f64().max(1e-12);
    let mut runner = analytic_runner(&registry, refitter, tolerance, time_scale, metrics.clone())
        .with_clock(clock.clone() as Arc<dyn Clock>);
    runner.track_deployed(clock.now());
    let handle = runner.policy().handle();
    if let Some(cfg) = coord {
        let c = Arc::new(RefreshCoordinator::new(cfg, handle.clone(), metrics.clone()));
        runner.set_coordinator(c);
    }
    SimRefresh {
        clock,
        registry,
        runner,
        handle,
        metrics,
    }
}

/// Drive one lane over an arrival trace to completion: arrivals join
/// the queue as their offsets pass, the refresh runner (when attached)
/// ticks at every step boundary — the pool's check cadence, so a due
/// hot-swap lands BETWEEN steps — and held lanes nap in small bounded
/// advances exactly like the worker loop. Idle gaps fast-forward to
/// the next arrival.
pub fn drive_decode(
    sim: &mut SimDecode,
    registry: &SharedRegistry,
    handle: Option<&RefreshHandle>,
    mut runner: Option<&mut RefreshRunner>,
    task: &str,
    arrivals: &[DecodeArrival],
) {
    let t0 = sim.clock.now();
    let mut next = 0;
    let mut guard = 0usize;
    loop {
        while next < arrivals.len() && t0 + arrivals[next].at <= sim.clock.now() {
            sim.enqueue(arrivals[next].prompt.clone(), arrivals[next].gen_len);
            next += 1;
        }
        if let Some(r) = runner.as_deref_mut() {
            r.tick(sim.clock.now());
        }
        match sim.step(registry, handle, task) {
            DecodeOutcome::Progressed => {}
            DecodeOutcome::Held(until) => {
                let nap = until
                    .saturating_duration_since(sim.clock.now())
                    .min(sim.step_time(1))
                    .max(Duration::from_nanos(1));
                sim.clock.advance(nap);
            }
            DecodeOutcome::Idle => {
                let Some(a) = arrivals.get(next) else { break };
                let nap = (t0 + a.at)
                    .saturating_duration_since(sim.clock.now())
                    .max(Duration::from_nanos(1));
                sim.clock.advance(nap);
            }
        }
        guard += 1;
        assert!(guard < 4_000_000, "decode trace must terminate");
    }
}

// ---------------------------------------------------------------------------
// Bounded adapter-cache sim (cache_conformance + serving_cache)
// ---------------------------------------------------------------------------

/// Deterministic zipf-ish demand trace over `n_tasks` task indices:
/// task rank `r` is drawn with weight `1/(r+1)`, so a hot head stays
/// near-resident while a long tail of cold tasks forces churn — the
/// many-more-tasks-than-DPU-memory regime the capacity tier exists
/// for. PCG-seeded, so suite and bench replay the identical trace.
pub fn zipf_trace(n_requests: usize, n_tasks: usize, seed: u64) -> Vec<usize> {
    assert!(n_tasks > 0);
    let weights: Vec<f64> = (0..n_tasks).map(|r| 1.0 / (r + 1) as f64).collect();
    let total: f64 = weights.iter().sum();
    let mut rng = Pcg64::new(seed);
    (0..n_requests)
        .map(|_| {
            let x = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * total;
            let mut acc = 0.0;
            for (i, w) in weights.iter().enumerate() {
                acc += w;
                if x < acc {
                    return i;
                }
            }
            n_tasks - 1
        })
        .collect()
}

/// Strictly periodic round-robin trace: request `i` targets task
/// `i % n_tasks`, so every task arrives on a fixed period — the
/// pattern the arrival-EWMA prefetcher predicts perfectly, and the
/// worst case for plain LRU when `n_tasks` exceeds capacity (every
/// demand arrival finds its adapter just evicted).
pub fn periodic_trace(n_requests: usize, n_tasks: usize) -> Vec<usize> {
    (0..n_requests).map(|i| i % n_tasks).collect()
}

/// One worker's demand stream against the capacity tier on the virtual
/// clock: each drive step advances the clock by one inter-arrival,
/// completes due loads ([`AdapterCache::poll`]), runs the predictive
/// prefetcher off the scheduler's arrival EWMAs (a no-op when the
/// config disables it), then issues one demand lookup — exactly the
/// worker-loop order in `serve::pool`. Residency invariants (capacity
/// bound, pin stability) are asserted after EVERY event, so "at every
/// instant" claims are exact on the virtual clock, not sampled.
pub struct CacheSim {
    pub clock: Arc<VirtualClock>,
    pub registry: SharedRegistry,
    pub cache: Arc<AdapterCache>,
    pub metrics: Arc<Metrics>,
    sched: BatchScheduler,
    pub tasks: Vec<String>,
    /// Most adapters simultaneously resident, observed at every event.
    pub max_resident: usize,
    /// Pinned tasks seen resident at least once — they must stay
    /// resident forever after (checked at every event).
    landed_pins: Vec<String>,
    /// Per-SERVED-request cold penalty, ns (0 = immediate hit; a cold
    /// request waits out its load's `ready_at`).
    pub cold_ns: Vec<f64>,
    pub served: usize,
    /// Requests shed by the bounded load queue (typed `Shed` outcome;
    /// every one is accounted — `served + shed == trace length`).
    pub shed: usize,
}

/// `n_tasks` deployed tasks over the capacity tier `cfg` describes, on
/// a fresh shared [`VirtualClock`]. Task `i` is named `task{i:02}`.
pub fn cache_sim(n_tasks: usize, cfg: CacheConfig) -> CacheSim {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    let metrics = Arc::new(Metrics::default());
    let cache = AdapterCache::new(
        cfg,
        registry.clone(),
        clock.clone() as Arc<dyn Clock>,
        metrics.clone(),
    );
    let tasks: Vec<String> = (0..n_tasks).map(|i| format!("task{i:02}")).collect();
    for t in &tasks {
        registry.deploy(t, adapter(1.0));
    }
    // drain the admission queue (and evict down to capacity) before the
    // trace starts, so warmup state is deterministic
    cache.poll(clock.now());
    CacheSim {
        clock,
        registry,
        cache,
        metrics,
        sched: BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            MAX_BATCH,
            Duration::from_millis(5),
        ),
        tasks,
        max_resident: 0,
        landed_pins: Vec::new(),
        cold_ns: Vec::new(),
        served: 0,
        shed: 0,
    }
}

impl CacheSim {
    /// Residency invariants, asserted after every event: the capacity
    /// bound holds at this instant, and no pinned task that ever became
    /// resident has been evicted.
    fn check_invariants(&mut self) {
        let n = self.cache.resident_count();
        assert!(
            n <= self.cache.capacity(),
            "resident {} exceeds capacity {}",
            n,
            self.cache.capacity()
        );
        self.max_resident = self.max_resident.max(n);
        for t in &self.tasks {
            if self.cache.is_pinned(t) && self.cache.is_resident(t) {
                if !self.landed_pins.contains(t) {
                    self.landed_pins.push(t.clone());
                }
            }
        }
        for t in &self.landed_pins {
            assert!(
                self.cache.is_resident(t),
                "pinned task {t} was evicted after becoming resident"
            );
        }
    }

    /// Drive the demand trace, one request per `ia` of virtual time.
    /// Cold requests are modeled as waiting out their load (`ready_at`
    /// − now, the penalty log the suite and bench aggregate); shed
    /// requests are counted, never silently dropped.
    pub fn drive(&mut self, trace: &[usize], ia: Duration) {
        for &idx in trace {
            self.clock.advance(ia);
            let now = self.clock.now();
            self.cache.poll(now);
            self.check_invariants();
            self.cache.prefetch(now, &self.sched.arrival_rates());
            let task = self.tasks[idx].clone();
            self.sched.observe_arrival(&task, now);
            match self.cache.lookup(&task, now, 1) {
                CacheLookup::Hit => {
                    self.served += 1;
                    self.cold_ns.push(0.0);
                }
                CacheLookup::Loading { ready_at } | CacheLookup::Queued { ready_at } => {
                    self.served += 1;
                    self.cold_ns
                        .push(ready_at.saturating_duration_since(now).as_nanos() as f64);
                }
                CacheLookup::Shed => self.shed += 1,
                CacheLookup::Unknown => panic!("trace task {task} was deployed"),
            }
            self.check_invariants();
        }
        // land the tail: loads still in flight complete
        let mut rounds = 0;
        while self.cache.loading_count() > 0 {
            self.clock.advance(ia.max(Duration::from_nanos(1)));
            self.cache.poll(self.clock.now());
            self.check_invariants();
            rounds += 1;
            assert!(rounds < 8192, "in-flight loads must land");
        }
    }

    /// Fraction of served requests that hit a resident adapter.
    pub fn hit_rate(&self) -> f64 {
        if self.cold_ns.is_empty() {
            return 0.0;
        }
        self.cold_ns.iter().filter(|&&x| x == 0.0).count() as f64 / self.cold_ns.len() as f64
    }

    /// p99 of the per-request cold penalty, ms — the number the
    /// predictive prefetcher is judged on.
    pub fn cold_p99_ms(&self) -> f64 {
        stats::percentile(&self.cold_ns, 99.0) / 1e6
    }

    pub fn mean_cold_ms(&self) -> f64 {
        stats::mean(&self.cold_ns) / 1e6
    }
}
