//! Cross-module integration over the simulation substrates (no PJRT):
//! PCM ⊗ AIMC mapping ⊗ pipeline ⊗ data ⊗ metrics, plus property-based
//! sweeps on the end-to-end device pipeline.

use ahwa_lora::aimc::mapping::program_tensor;
use ahwa_lora::aimc::quant;
use ahwa_lora::data::glue::{GlueGen, ALL_TASKS};
use ahwa_lora::data::squad::SquadTask;
use ahwa_lora::eval::metrics;
use ahwa_lora::pcm::drift::DRIFT_TIMES;
use ahwa_lora::pcm::{read_tensor, PcmModel};
use ahwa_lora::pipeline::balance::{best, sweep};
use ahwa_lora::pmca::cluster::SnitchCluster;
use ahwa_lora::pmca::redmule::RedMulE;
use ahwa_lora::util::proptest;
use ahwa_lora::util::rng::Pcg64;

/// The full device pipeline must be *unbiased* at t=0 with compensation:
/// programming + read noise average out around the target weights.
#[test]
fn pcm_pipeline_is_unbiased_property() {
    proptest::check("pcm-unbiased", 8, |g| {
        let rows = g.usize_in(16, 64);
        let cols = g.usize_in(2, 8);
        let w = g.vec_normal(rows * cols, 0.0, 0.05);
        let model = PcmModel::default();
        let trials = 24;
        let mut mean = vec![0f32; w.len()];
        for trial in 0..trials {
            let mut rng = Pcg64::with_stream(g.seed, trial);
            let t = program_tensor(&model, &w, rows, cols, 0.0, &mut rng);
            let got = read_tensor(&model, &t, 0.0, true, &mut rng);
            for (m, v) in mean.iter_mut().zip(&got) {
                *m += v / trials as f32;
            }
        }
        // per-weight bias below ~half the programming-noise scale
        let wmax = w.iter().fold(0f32, |m, x| m.max(x.abs()));
        for (m, target) in mean.iter().zip(&w) {
            assert!(
                (m - target).abs() < 0.5 * wmax,
                "bias {m} vs {target} (wmax {wmax})"
            );
        }
    });
}

/// Weight error must grow monotonically (statistically) along the
/// paper's drift grid — the mechanism behind every drift table.
#[test]
fn drift_grid_error_is_monotone() {
    let model = PcmModel::default();
    let mut rng = Pcg64::new(42);
    let mut w = vec![0f32; 128 * 16];
    rng.fill_normal(&mut w, 0.0, 0.05);
    let t = program_tensor(&model, &w, 128, 16, 3.0, &mut rng);

    let mut errs = Vec::new();
    for (_, secs) in DRIFT_TIMES {
        let mut e = 0f64;
        for trial in 0..6 {
            let mut r = Pcg64::with_stream(7, trial);
            let got = read_tensor(&model, &t, secs, true, &mut r);
            e += got.iter().zip(&w).map(|(a, b)| ((a - b) * (a - b)) as f64).sum::<f64>();
        }
        errs.push(e);
    }
    assert!(errs[6] > errs[0] * 1.2, "10y {:.4} vs 0s {:.4}", errs[6], errs[0]);
    // the long end must be ordered even if adjacent short times jitter
    assert!(errs[6] > errs[2], "{errs:?}");
    assert!(errs[5] > errs[1], "{errs:?}");
}

/// Quantizer + mapping compose: an 8-bit ADC read of a programmed
/// tensor is closer to the ideal than a 4-bit one.
#[test]
fn quantized_readout_error_ordering() {
    let model = PcmModel::ideal();
    let mut rng = Pcg64::new(3);
    let mut w = vec![0f32; 256 * 4];
    rng.fill_normal(&mut w, 0.0, 0.1);
    let t = program_tensor(&model, &w, 256, 4, 0.0, &mut rng);
    let clean = read_tensor(&model, &t, 0.0, false, &mut rng);
    let err = |bits: u32| {
        let mut v = clean.clone();
        quant::quant_block(&mut v, quant::levels_for_bits(bits));
        v.iter().zip(&w).map(|(a, b)| ((a - b) * (a - b)) as f64).sum::<f64>()
    };
    assert!(err(4) > err(6));
    assert!(err(6) > err(8));
}

/// Every paper operating point (layer x T_int) has a balance choice
/// whose steady-state overhead is low for at least one integration time.
#[test]
fn pipeline_balance_exists_for_paper_grid() {
    let (c, e) = (SnitchCluster::default(), RedMulE::default());
    for (m, n) in [(128usize, 128usize), (512, 128)] {
        let mut best_overhead = f64::INFINITY;
        for t_int in [128.0, 256.0, 512.0] {
            let b = best(&sweep(m, n, 8, t_int, 320, &c, &e));
            best_overhead = best_overhead.min(b.latency.overhead());
            assert!(b.fits_tcdm, "{m}x{n}@{t_int} spilled TCDM");
        }
        assert!(best_overhead < 0.05, "{m}x{n}: best overhead {best_overhead}");
    }
}

/// Rank sweep through the pipeline: the PMCA cost axis of Fig. 2a.
/// Latency is non-decreasing in r; at low rank the (rank-independent)
/// DMA hand-off dominates, so the curve is flat there and strictly
/// increasing once compute takes over — exactly why the paper can
/// afford rank 8.
#[test]
fn rank_cost_axis_monotone() {
    let (c, e) = (SnitchCluster::default(), RedMulE::default());
    let lat = |r| {
        ahwa_lora::pmca::kernels::LoraWorkload { m: 128, n: 128, r, t: 64 }.latency_ns(&c, &e)
    };
    let mut last = 0.0;
    for r in [1usize, 2, 4, 8, 16] {
        let l = lat(r);
        assert!(l >= last, "r={r}: {l} < {last}");
        last = l;
    }
    assert!(lat(16) > lat(1), "compute must dominate by r=16");
    // compute cycles alone are strictly monotone in r
    let compute = |r| {
        ahwa_lora::pmca::kernels::LoraWorkload { m: 128, n: 128, r, t: 64 }
            .cycles(&c, &e)
            .compute()
    };
    assert!(compute(2) > compute(1) && compute(16) > compute(8));
}

/// Synthetic task suite ⊗ metric zoo: oracle predictions score 100,
/// adversarial ones score low, on every GLUE task.
#[test]
fn glue_tasks_metric_roundtrip() {
    for task in ALL_TASKS {
        let gen = GlueGen::new(task, 512, 48);
        let mut rng = Pcg64::new(11);
        let b = gen.batch(200, &mut rng);
        if task.is_regression() {
            let golds: Vec<f64> = b.targets.iter().map(|&x| x as f64).collect();
            let perfect = metrics::pearson_spearman(&golds, &golds);
            assert!((perfect - 100.0).abs() < 1e-9);
        } else {
            let acc = metrics::accuracy(&b.labels, &b.labels);
            assert_eq!(acc, 100.0, "{task:?}");
            let wrong: Vec<i32> = b.labels.iter().map(|&l| 1 - l.min(1)).collect();
            assert!(metrics::accuracy(&wrong, &b.labels) < 60.0, "{task:?}");
        }
    }
}

/// QA generator ⊗ span metrics: gold spans score 100/100; spans offset
/// by one position score <100 EM but >0 F1 (token overlap survives).
#[test]
fn squad_metric_composition() {
    let task = SquadTask::new(512, 48);
    let mut rng = Pcg64::new(5);
    let batch = task.batch(64, &mut rng);
    let golds: Vec<(usize, usize)> = batch
        .starts
        .iter()
        .zip(&batch.ends)
        .map(|(&s, &e)| (s as usize, e as usize))
        .collect();
    let (f1, em) = metrics::span_f1_em(&golds, &golds);
    assert_eq!((f1, em), (100.0, 100.0));
    let shifted: Vec<(usize, usize)> = golds.iter().map(|&(s, e)| (s + 1, e + 1)).collect();
    let (f1s, ems) = metrics::span_f1_em(&shifted, &golds);
    assert!(ems < 5.0);
    assert!(f1s > 10.0 && f1s < 95.0, "f1={f1s}");
}

/// GSM ⊗ reward: corrupting the working-out tags costs exactly that
/// reward component.
#[test]
fn gsm_reward_component_sensitivity() {
    use ahwa_lora::data::gsm::GsmTask;
    use ahwa_lora::data::tokenizer::{EOW, SOW};
    use ahwa_lora::rl::reward::{score, MAX_REWARD};

    let task = GsmTask::new(64);
    let mut rng = Pcg64::new(9);
    for _ in 0..20 {
        let p = task.problem(&mut rng);
        let ideal = p.ideal_completion();
        assert_eq!(score(&ideal, p.answer()).total(), MAX_REWARD);

        // break the working-out tags only: lose exactly 1.0
        let mut no_work = ideal.clone();
        for t in no_work.iter_mut() {
            if *t == SOW || *t == EOW {
                *t = 40;
            }
        }
        assert_eq!(score(&no_work, p.answer()).total(), MAX_REWARD - 1.0);
    }
}
