//! Serving-stack integration: client → sharded engine pool (PJRT) →
//! typed responses, with backpressure, injected batch failures, adapter
//! hot-swaps mid-stream, and graceful drain. The PJRT-backed tests need
//! artifacts and self-skip without them; the drift-refresh and registry
//! race tests are hermetic (virtual clock, zero real sleeps).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest};
use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::model::checkpoint;
use ahwa_lora::model::params::{ParamStore, Tensor};
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    submit_wave, BuildError, Clock, CoordConfig, DecayModel, FnRefitter, Metrics, Pending, Refit,
    RefreshConfig, RefreshCoordinator, RefreshRunner, SchedConfig, ServeError, Server,
    ServerBuilder, VirtualClock,
};
use ahwa_lora::util::rng::Pcg64;

fn ready() -> bool {
    let ok = default_artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

/// Deploy `tasks` on a fresh registry and build a "tiny" server with
/// test-friendly batching defaults, customised by `cfg`.
fn setup(
    tasks: &[GlueTask],
    cfg: impl FnOnce(ServerBuilder) -> ServerBuilder,
) -> anyhow::Result<(Server, usize, usize)> {
    let manifest = Manifest::load(default_artifacts_dir())?;
    let v = manifest.variant("tiny")?.clone();
    let meta = checkpoint::load(manifest.init_path("tiny.meta"))?;
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train"))?;
    let registry = SharedRegistry::new();
    for t in tasks {
        registry.deploy(t.adapter_key(), adapter.clone());
    }
    let builder = cfg(Server::builder("tiny")
        .manifest(manifest)
        .max_batch(4)
        .max_wait(Duration::from_millis(2)));
    let server = builder.build(meta, registry)?;
    Ok((server, v.vocab, v.seq))
}

fn jobs_for(tasks: &[GlueTask], vocab: usize, seq: usize, n: usize, seed: u64) -> Vec<(String, Vec<i32>)> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|i| {
            let task = tasks[i % tasks.len()];
            let gen = GlueGen::new(task, vocab, seq);
            let (tokens, _, _) = gen.example(&mut rng);
            (task.adapter_key().to_string(), tokens)
        })
        .collect()
}

#[test]
fn multi_worker_mixed_wave_zero_lost() {
    if !ready() {
        return;
    }
    // SST-2 and QNLI are pinned to DIFFERENT workers under FNV-1a % 2
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let (server, vocab, seq) = setup(&tasks, |b| b.workers(2)).unwrap();
    let client = server.client();
    assert_ne!(client.shard_for("SST-2"), client.shard_for("QNLI"));

    let jobs = jobs_for(&tasks, vocab, seq, 24, 1);
    let responses = submit_wave(&client, &jobs).unwrap();
    assert_eq!(responses.len(), 24, "zero lost responses");
    for (r, (task, _)) in responses.iter().zip(&jobs) {
        assert_eq!(&r.task, task);
        assert_eq!(r.worker, client.shard_for(task), "task stays on its shard");
        assert_eq!(r.logits.len(), 4); // padded n_cls
        assert!(r.logits.iter().all(|x| x.is_finite()));
        assert!(r.batch_size >= 1 && r.batch_size <= 4);
    }
    // per-worker AND aggregate accounting must line up
    let per_worker: Vec<u64> = server
        .worker_metrics()
        .iter()
        .map(|m| m.served.load(Ordering::Relaxed))
        .collect();
    assert_eq!(per_worker.len(), 2);
    assert!(per_worker.iter().all(|&s| s > 0), "both workers served: {per_worker:?}");
    let agg = server.metrics();
    assert_eq!(agg.served, 24);
    assert_eq!(per_worker.iter().sum::<u64>(), 24);
    assert!(agg.adapter_swaps >= 2);
    assert_eq!(agg.errors, 0);
    let report = server.metrics_report();
    assert!(report.contains("worker0") && report.contains("worker1"));
    server.shutdown().unwrap();
}

#[test]
fn pipeline_scheduler_serves_wave_and_reports_model() {
    if !ready() {
        return;
    }
    // same wave as the fixed batcher, but batch fills come from the
    // AIMC/PMCA cost model; every ticket must still resolve and the
    // pool must report modeled batch latency next to the measured one
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let v = Manifest::load(default_artifacts_dir())
        .unwrap()
        .variant("tiny")
        .unwrap()
        .clone();
    let (server, vocab, seq) = setup(&tasks, |b| {
        b.workers(2)
            .scheduler(SchedConfig::for_layer(v.d_model, v.d_model, v.rank))
    })
    .unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 24, 7);
    let responses = submit_wave(&client, &jobs).unwrap();
    assert_eq!(responses.len(), 24, "zero lost responses under the scheduler");
    for (r, (task, _)) in responses.iter().zip(&jobs) {
        assert_eq!(&r.task, task);
        assert!(r.logits.iter().all(|x| x.is_finite()));
    }
    let agg = server.metrics();
    assert_eq!(agg.served, 24);
    assert_eq!(agg.errors, 0);
    assert!(agg.modeled_p50_ms > 0.0, "modeled latency recorded: {agg:?}");
    assert!(server.metrics_report().contains("model_p50"));
    server.shutdown().unwrap();
}

#[test]
fn injected_batch_failures_still_resolve_every_ticket() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let (server, vocab, seq) = setup(&tasks, |b| b.workers(2).inject_batch_failure(2)).unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 16, 2);
    let pendings: Vec<Pending> = jobs
        .iter()
        .map(|(task, toks)| client.submit(task, toks).unwrap())
        .collect();
    let mut oks = 0u64;
    let mut errs = 0u64;
    for p in pendings {
        match p.wait() {
            Ok(r) => {
                assert!(r.logits.iter().all(|x| x.is_finite()));
                oks += 1;
            }
            Err(ServeError::Batch { detail, .. }) => {
                assert!(detail.contains("injected"));
                errs += 1;
            }
            Err(e) => panic!("unexpected error kind: {e}"),
        }
    }
    assert_eq!(oks + errs, 16, "every admitted ticket resolved");
    assert!(errs > 0, "fault injection fired");
    assert!(oks > 0, "healthy batches still served");
    assert_eq!(server.metrics().errors, errs);
    server.shutdown().unwrap();
}

#[test]
fn bounded_queue_backpressure_returns_overloaded() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    // one worker, 2 in-flight slots, and a batch deadline far enough out
    // that the queue cannot drain while we hammer it
    let (server, vocab, seq) = setup(&tasks, |b| {
        b.workers(1)
            .queue_depth(2)
            .max_batch(8)
            .max_wait(Duration::from_secs(2))
    })
    .unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 6, 3);
    let mut admitted = Vec::new();
    let mut overloaded = 0u64;
    for (task, toks) in &jobs {
        match client.submit(task, toks) {
            Ok(p) => admitted.push(p),
            Err(ServeError::Overloaded { worker, depth }) => {
                assert_eq!(worker, 0);
                assert_eq!(depth, 2);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error kind: {e}"),
        }
    }
    // a scheduler stall can let the deadline fire and free slots
    // mid-loop, so bound rather than pin the split
    assert!(admitted.len() >= 2, "at least queue_depth admissions");
    assert_eq!(overloaded, 6 - admitted.len() as u64);
    assert!(overloaded >= 1, "the bounded queue pushed back");
    assert_eq!(server.metrics().rejected, overloaded);
    for p in admitted {
        assert!(p.wait().is_ok(), "admitted requests still served");
    }
    // slots freed -> the try-again protocol succeeds
    let p = client
        .submit_with_retry(&jobs[0].0, &jobs[0].1, Duration::from_secs(10))
        .unwrap();
    assert!(p.wait().is_ok());
    server.shutdown().unwrap();
}

#[test]
fn concurrent_redeploy_is_version_monotonic() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    let (server, vocab, seq) = setup(&tasks, |b| b.workers(1)).unwrap();
    let client = server.client();
    let registry = server.registry().clone();
    let adapter = {
        let manifest = Manifest::load(default_artifacts_dir()).unwrap();
        checkpoint::load(manifest.init_path("tiny.step_cls_lora.train")).unwrap()
    };

    let redeployer = std::thread::spawn(move || {
        for _ in 0..5 {
            registry.deploy("SST-2", adapter.clone());
            std::thread::sleep(Duration::from_millis(3));
        }
    });
    let mut versions = Vec::new();
    for wave in 0..4 {
        let jobs = jobs_for(&tasks, vocab, seq, 8, 10 + wave);
        for r in submit_wave(&client, &jobs).unwrap() {
            versions.push(r.adapter_version);
        }
    }
    redeployer.join().unwrap();

    let final_version = server.registry().version("SST-2").unwrap();
    assert_eq!(final_version, 6, "1 initial + 5 redeploys");
    assert!(versions.iter().all(|&v| v >= 1 && v <= final_version));
    // single worker + single task => batches are FIFO, so the observed
    // version sequence never goes backwards
    assert!(
        versions.windows(2).all(|w| w[0] <= w[1]),
        "versions observed monotonically: {versions:?}"
    );
    // after the redeployer is done, traffic sees the final version
    let jobs = jobs_for(&tasks, vocab, seq, 4, 99);
    for r in submit_wave(&client, &jobs).unwrap() {
        assert_eq!(r.adapter_version, final_version);
    }
    server.shutdown().unwrap();
}

#[test]
fn shutdown_drains_all_pending_requests() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    // deadline far in the future: ONLY the drain path can release these
    let (server, vocab, seq) = setup(&tasks, |b| {
        b.max_batch(8).max_wait(Duration::from_secs(60))
    })
    .unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 3, 4);
    let pendings: Vec<Pending> = jobs
        .iter()
        .map(|(task, toks)| client.submit(task, toks).unwrap())
        .collect();
    server.shutdown().unwrap();
    for p in pendings {
        let r = p.wait().expect("drained response");
        assert_eq!(r.task, "SST-2");
    }
    // surviving client handles are refused cleanly
    assert_eq!(
        client.submit("SST-2", &jobs[0].1).unwrap_err(),
        ServeError::ShuttingDown
    );
}

#[test]
fn typed_rejections_and_live_task_deploys() {
    if !ready() {
        return;
    }
    let (server, _, seq) = setup(&[GlueTask::Sst2], |b| b).unwrap();
    let client = server.client();
    assert!(matches!(
        client.submit("made-up-task", &vec![0; seq]).unwrap_err(),
        ServeError::UnknownTask { .. }
    ));
    assert_eq!(
        client.submit("SST-2", &vec![0; seq + 1]).unwrap_err(),
        ServeError::BadShape { got: seq + 1, want: seq }
    );
    // tasks deployed AFTER startup are immediately routable (the old
    // Router froze its task list at start)
    let manifest = Manifest::load(default_artifacts_dir()).unwrap();
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train")).unwrap();
    server.registry().deploy("QNLI", adapter);
    let v = manifest.variant("tiny").unwrap().clone();
    let mut rng = Pcg64::new(5);
    let (tokens, _, _) = GlueGen::new(GlueTask::Qnli, v.vocab, v.seq).example(&mut rng);
    let r = client.submit("QNLI", &tokens).unwrap().wait().unwrap();
    assert_eq!(r.task, "QNLI");
    server.shutdown().unwrap();
}

/// Adapter whose single value encodes a deployment tag, so readers can
/// verify an (adapter, version) pairing was never torn.
fn tagged_adapter(tag: f32) -> ParamStore {
    ParamStore::from_tensors(vec![Tensor {
        name: "lora.a".to_string(),
        shape: vec![1],
        data: vec![tag],
    }])
}

/// Hermetic e2e drift-refresh cycle on the virtual clock (zero real
/// sleeps): drive a deployment past its drift threshold and assert the
/// refresh triggers at the modeled time, the registry version bumps
/// exactly once, no reader ever observes a torn or stale-beyond-
/// tolerance adapter, and predicted decay after the swap is back below
/// threshold.
#[test]
fn drift_refresh_triggers_at_modeled_time_and_hot_swaps_once() {
    let clock = VirtualClock::new();
    let registry = SharedRegistry::new();
    assert_eq!(registry.deploy("SST-2", tagged_adapter(1.0)), 1);

    let tol = 0.05;
    let refit_calls = Arc::new(AtomicU64::new(0));
    let refitter = {
        let refit_calls = refit_calls.clone();
        FnRefitter(
            move |task: &str,
                  current: &ParamStore,
                  _meta: &ParamStore,
                  budget: usize|
                  -> anyhow::Result<Refit> {
                refit_calls.fetch_add(1, Ordering::Relaxed);
                assert_eq!(task, "SST-2");
                assert_eq!(current.tensors[0].data[0], 1.0, "refit sees the live adapter");
                Ok(Refit { params: tagged_adapter(2.0), steps: budget.min(7) })
            },
        )
    };
    let cfg = RefreshConfig::new(
        DecayModel::analytic(PcmModel::default()),
        Arc::new(refitter),
    )
    .tolerance(tol)
    .step_budget(16);

    let metrics = Arc::new(Metrics::default());
    let mut runner = RefreshRunner::new(
        cfg,
        registry.clone(),
        Arc::new(ParamStore::default()),
        metrics.clone(),
    );
    runner.track_deployed(clock.now());

    // the policy's modeled trigger: closed-form inverse of the decay model
    let age_star = runner.policy().trigger_age_secs("SST-2").unwrap();
    assert!(age_star > 0.0 && age_star.is_finite());

    // concurrent reader playing the request path: every snapshot must be
    // a consistent (adapter, version) pair, versions monotone
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let (registry, stop) = (registry.clone(), stop.clone());
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut saw = 0u64;
            loop {
                let stopping = stop.load(Ordering::Acquire);
                let (adapter, version) = registry.snapshot("SST-2").expect("deployed");
                assert!(version >= last, "version went backwards: {version} < {last}");
                last = version;
                let tag = adapter.tensors[0].data[0];
                match version {
                    1 => assert_eq!(tag, 1.0, "torn read: v1 paired with tag {tag}"),
                    2 => assert_eq!(tag, 2.0, "torn read: v2 paired with tag {tag}"),
                    v => panic!("unexpected version {v}"),
                }
                saw += 1;
                if stopping {
                    // one guaranteed post-stop snapshot: the swap done
                    // before `stop` was set must be visible by now
                    return (last, saw);
                }
                std::thread::yield_now();
            }
        })
    };

    // 1% before the modeled trigger: nothing is due
    clock.advance(Duration::from_secs_f64(age_star * 0.99));
    assert!(runner.tick(clock.now()).is_empty(), "must not refresh early");
    assert_eq!(registry.version("SST-2"), Some(1));
    assert!(runner.policy().predicted_decay("SST-2", clock.now()).unwrap() < tol);

    // 1% past it: exactly one refresh at the modeled time
    clock.advance(Duration::from_secs_f64(age_star * 0.02));
    let events = runner.tick(clock.now());
    assert_eq!(events.len(), 1, "refresh fires at the modeled trigger time");
    let ev = &events[0];
    assert_eq!(ev.task, "SST-2");
    assert_eq!(ev.version, 2, "hot-swap installed version 2");
    assert!(
        (ev.drift_age_secs - age_star * 1.01).abs() < age_star * 1e-6,
        "triggered at the modeled drift age: {} vs {age_star}",
        ev.drift_age_secs
    );
    assert!(ev.pre_decay >= tol, "decay had crossed tolerance: {}", ev.pre_decay);
    assert!(ev.post_decay < tol, "decay after swap is below threshold: {}", ev.post_decay);
    assert_eq!(ev.steps, 7, "bounded refit budget is reported");

    // the swap is immediately visible and never beyond tolerance again
    assert_eq!(registry.version("SST-2"), Some(2));
    assert_eq!(registry.get("SST-2").unwrap().tensors[0].data[0], 2.0);
    assert!(runner.policy().predicted_decay("SST-2", clock.now()).unwrap() < tol);

    // exactly once: the drift clock restarted, nothing further is due
    assert!(runner.tick(clock.now()).is_empty());
    assert_eq!(registry.version("SST-2"), Some(2), "version bumped exactly once");
    assert_eq!(refit_calls.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.refreshes.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.refresh_steps.load(Ordering::Relaxed), 7);

    stop.store(true, Ordering::Release);
    let (last, saw) = reader.join().unwrap();
    assert_eq!(last, 2, "the reader observed the hot-swap");
    assert!(saw > 0, "the reader actually raced the swap");
}

/// Regression (hermetic, virtual clock): a manual `deploy` racing a
/// coordinator re-phase must keep the drift clock monotone. The
/// runner-path re-anchor was already covered above
/// (`manual redeploy between ticks`-style, in refresh.rs); this pins
/// the NEW hazard the pool coordinator introduces — a stagger computed
/// for the OLD deployment's trigger surviving onto the re-anchored
/// drift clock would refit the operator's fresh adapter at the stale
/// (earlier) instant.
#[test]
fn manual_deploy_racing_a_coordinator_rephase_keeps_the_drift_clock_monotone() {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    registry.deploy("t", tagged_adapter(1.0));
    registry.deploy("u", tagged_adapter(1.0));

    let bump = FnRefitter(
        |_: &str, cur: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
            Ok(Refit {
                params: tagged_adapter(cur.tensors[0].data[0] + 1.0),
                steps: budget,
            })
        },
    );
    let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
    let cfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), Arc::new(bump))
        .tolerance(0.05)
        .time_scale(age / 10.0); // both triggers land ~10s out
    let metrics = Arc::new(Metrics::default());
    let mut runner = RefreshRunner::new(
        cfg,
        registry.clone(),
        Arc::new(ParamStore::default()),
        metrics.clone(),
    )
    .with_clock(clock.clone() as Arc<dyn Clock>);
    runner.track_deployed(clock.now());
    let handle = runner.policy().handle();
    runner.set_coordinator(Arc::new(RefreshCoordinator::new(
        CoordConfig::default()
            .max_concurrent_holds(1)
            .slack(Duration::from_secs(5))
            .fallback_window(Duration::from_millis(500))
            .fallback_hold(Duration::from_millis(500)),
        handle.clone(),
        metrics,
    )));

    let modeled = handle.trigger_at("t").unwrap();
    assert_eq!(handle.trigger_at("u"), Some(modeled), "shared tolerance, shared crossing");

    // first tick: the coordinator re-phases the colliding triggers —
    // "t" (earlier in the deterministic order) is pulled a span earlier
    assert!(runner.tick(clock.now()).is_empty(), "nothing due yet");
    let staggered = handle.staggered_at("t").expect("t was re-phased");
    assert!(staggered < modeled, "stagger only ever moves earlier");
    assert_eq!(handle.staggered_at("u"), None, "the latest trigger keeps its phase");

    // an operator hot-swaps a fresh adapter BETWEEN ticks, racing the
    // re-phase...
    clock.advance(Duration::from_secs(2));
    registry.deploy("t", tagged_adapter(7.0));
    let deployed_at = clock.now();

    // ...and the next tick re-anchors: version adopted, and the stagger
    // computed for the OLD deployment does not survive onto the new
    // drift clock
    assert!(runner.tick(clock.now()).is_empty());
    assert_eq!(runner.policy().tracked_version("t"), Some(2));
    let new_modeled = handle.trigger_at("t").unwrap();
    assert!(new_modeled > modeled, "re-anchor moves the crossing forward, never backward");
    let effective = handle.staggered_at("t").unwrap_or(new_modeled);
    assert!(
        effective > deployed_at,
        "monotone: the new deployment's trigger lies in its own future"
    );

    // at the OLD deployment's staggered and modeled instants nothing
    // fires for 't' (the sibling 'u' refreshes on its own schedule)
    clock.advance(staggered - clock.now() + Duration::from_millis(1));
    assert!(
        runner.tick(clock.now()).iter().all(|e| e.task != "t"),
        "a stale stagger must not refit the fresh adapter"
    );
    clock.advance(modeled - clock.now() + Duration::from_millis(1));
    assert!(
        runner.tick(clock.now()).iter().all(|e| e.task != "t"),
        "the stale modeled crossing must not refit either"
    );
    assert_eq!(registry.version("t"), Some(2), "operator's adapter survives untouched");
    assert!(
        runner.policy().tracked_version("u").unwrap() >= 2,
        "the sibling task refreshed normally through the race"
    );

    // from the re-anchored clock 't' completes its cycle normally
    let eff = handle
        .staggered_at("t")
        .unwrap_or_else(|| handle.trigger_at("t").unwrap());
    clock.advance(eff - clock.now() + Duration::from_millis(1));
    let evs = runner.tick(clock.now());
    assert!(
        evs.iter().any(|e| e.task == "t" && e.version == 3),
        "re-anchored cycle completes: {evs:?}"
    );
}

/// Hermetic stress test pinning `SharedRegistry` version monotonicity
/// under concurrent `deploy` + `snapshot` races.
#[test]
fn registry_versions_monotone_under_concurrent_deploy_and_snapshot() {
    // Phase 1 — pairing: one writer deploys adapters whose payload
    // encodes the version they will get; readers must never see a torn
    // (adapter, version) pair.
    let reg = SharedRegistry::new();
    reg.deploy("t", tagged_adapter(1.0));
    let done = Arc::new(AtomicBool::new(false));
    let writer = {
        let (reg, done) = (reg.clone(), done.clone());
        std::thread::spawn(move || {
            for i in 2..=500u64 {
                let v = reg.deploy("t", tagged_adapter(i as f32));
                assert_eq!(v, i, "single writer sees sequential versions");
            }
            done.store(true, Ordering::Release);
        })
    };
    let readers: Vec<_> = (0..3)
        .map(|_| {
            let (reg, done) = (reg.clone(), done.clone());
            std::thread::spawn(move || {
                let mut last = 0u64;
                while !done.load(Ordering::Acquire) {
                    let (adapter, version) = reg.snapshot("t").expect("deployed");
                    assert!(version >= last, "monotone: {version} < {last}");
                    assert_eq!(
                        adapter.tensors[0].data[0], version as f32,
                        "torn read: payload does not match version"
                    );
                    last = version;
                    std::thread::yield_now();
                }
            })
        })
        .collect();
    writer.join().unwrap();
    for r in readers {
        r.join().unwrap();
    }
    assert_eq!(reg.version("t"), Some(500));

    // Phase 2 — multi-writer: N writers hammer the same task; every
    // version must be handed out exactly once and snapshots stay
    // monotone per reader.
    let reg = SharedRegistry::new();
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let (reg, stop) = (reg.clone(), stop.clone());
        std::thread::spawn(move || {
            let mut last = 0u64;
            while !stop.load(Ordering::Acquire) {
                if let Some((_, version)) = reg.snapshot("t") {
                    assert!(version >= last, "monotone under multi-writer races");
                    last = version;
                }
                std::thread::yield_now();
            }
        })
    };
    let writers: Vec<_> = (0..4)
        .map(|w| {
            let reg = reg.clone();
            std::thread::spawn(move || {
                for i in 0..200 {
                    reg.deploy("t", tagged_adapter((w * 1000 + i) as f32));
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    reader.join().unwrap();
    assert_eq!(reg.version("t"), Some(800), "4 writers x 200 deploys, no version lost");
}

/// Hermetic pin of every evict / restore / CAS-deploy interleaving the
/// capacity tier and the refresh worker can produce against one
/// registry entry (single-threaded, each ordering spelled out).
#[test]
fn eviction_interleaved_with_cas_deploy_never_resurrects_paged_out_adapters() {
    let reg = SharedRegistry::new();
    reg.deploy("t", tagged_adapter(1.0));

    // evict, then the refresh CAS computed against the evicted version:
    // the refit must NOT land behind the capacity tier's back
    let (bytes, v) = reg.evict("t").expect("deployed task evicts");
    assert_eq!(v, 1);
    assert!(reg.is_evicted("t") && !reg.contains("t"));
    assert_eq!(reg.deploy_if_version("t", tagged_adapter(2.0), 1), None);
    assert!(!reg.contains("t"), "a losing CAS must not resurrect the entry");

    // restore at the SAME version, then the CAS applies monotone
    assert!(reg.restore("t", bytes, v));
    assert_eq!(reg.version("t"), Some(1), "a reload is not a redeploy");
    assert_eq!(reg.deploy_if_version("t", tagged_adapter(2.0), 1), Some(2));

    // evict → manual deploy → the stale restore must lose: the operator
    // deployed newer bytes while the page-in was in flight
    let (bytes, v) = reg.evict("t").expect("evicts at v2");
    assert_eq!(v, 2);
    assert_eq!(
        reg.deploy("t", tagged_adapter(3.0)),
        3,
        "deploy resumes the retained counter monotone across the eviction"
    );
    assert!(
        !reg.restore("t", bytes, v),
        "restoring pre-eviction bytes over a newer deploy must fail"
    );
    assert_eq!(reg.version("t"), Some(3));
    assert_eq!(reg.get("t").unwrap().tensors[0].data[0], 3.0);
}

/// Hermetic stress: a pager thread cycling evict → restore races a
/// refresh-style snapshot → CAS thread. Pinned: versions stay monotone
/// with intact (payload, version) pairing for every reader, a CAS never
/// lands while the entry is paged out, and the final version equals
/// 1 + the CAS wins (no version lost or double-issued).
#[test]
fn cas_deploys_racing_evict_restore_stay_monotone_and_never_land_evicted() {
    let reg = SharedRegistry::new();
    reg.deploy("t", tagged_adapter(1.0));
    let stop = Arc::new(AtomicBool::new(false));

    let pager = {
        let (reg, stop) = (reg.clone(), stop.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                if let Some((bytes, v)) = reg.evict("t") {
                    std::thread::yield_now();
                    assert!(
                        reg.restore("t", bytes, v),
                        "nothing can outbid a restore here: CAS loses while evicted"
                    );
                }
                std::thread::yield_now();
            }
        })
    };
    let reader = {
        let (reg, stop) = (reg.clone(), stop.clone());
        std::thread::spawn(move || {
            let mut last = 0u64;
            while !stop.load(Ordering::Acquire) {
                if let Some((adapter, version)) = reg.snapshot("t") {
                    assert!(version >= last, "monotone across evict/restore churn");
                    assert_eq!(
                        adapter.tensors[0].data[0], version as f32,
                        "torn (payload, version) pair under paging races"
                    );
                    last = version;
                }
                std::thread::yield_now();
            }
        })
    };
    // refresh-style writer: snapshot, then CAS against the seen version
    // with a payload tagged for the version the win would produce
    let mut wins = 0u64;
    for _ in 0..2_000 {
        if let Some((_, v)) = reg.snapshot("t") {
            match reg.deploy_if_version("t", tagged_adapter((v + 1) as f32), v) {
                Some(nv) => {
                    assert_eq!(nv, v + 1, "CAS win bumps exactly once");
                    wins += 1;
                }
                None => {
                    // lost to an eviction between snapshot and CAS —
                    // the entry must not have materialised from it
                    if reg.is_evicted("t") {
                        assert!(!reg.contains("t"));
                    }
                }
            }
        }
        std::thread::yield_now();
    }
    stop.store(true, Ordering::Release);
    pager.join().unwrap();
    reader.join().unwrap();
    assert_eq!(
        reg.version("t"),
        Some(1 + wins),
        "every CAS win accounted, none lost to the paging churn"
    );
    assert!(reg.contains("t"), "the pager leaves the entry restored");
}

#[test]
fn builder_rejects_unknown_variant_and_graph() {
    if !ready() {
        return;
    }
    let manifest = Manifest::load(default_artifacts_dir()).unwrap();
    let meta = checkpoint::load(manifest.init_path("tiny.meta")).unwrap();
    let err = Server::builder("no-such-variant")
        .build(meta.clone(), SharedRegistry::new())
        .unwrap_err();
    assert!(matches!(err, BuildError::Manifest { .. }));
    // build errors stay representable as the serving error type
    assert!(matches!(ServeError::from(err), ServeError::Init { .. }));
    let err = Server::builder("tiny")
        .graph("tiny/no_such_graph")
        .build(meta, SharedRegistry::new())
        .unwrap_err();
    assert!(matches!(err, BuildError::Graph { .. }));
}
