//! Serving-stack integration: client → sharded engine pool (PJRT) →
//! typed responses, with backpressure, injected batch failures, adapter
//! hot-swaps mid-stream, and graceful drain. Needs artifacts.

use std::sync::atomic::Ordering;
use std::time::Duration;

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest};
use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::model::checkpoint;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{submit_wave, Pending, SchedConfig, ServeError, Server, ServerBuilder};
use ahwa_lora::util::rng::Pcg64;

fn ready() -> bool {
    let ok = default_artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

/// Deploy `tasks` on a fresh registry and build a "tiny" server with
/// test-friendly batching defaults, customised by `cfg`.
fn setup(
    tasks: &[GlueTask],
    cfg: impl FnOnce(ServerBuilder) -> ServerBuilder,
) -> anyhow::Result<(Server, usize, usize)> {
    let manifest = Manifest::load(default_artifacts_dir())?;
    let v = manifest.variant("tiny")?.clone();
    let meta = checkpoint::load(manifest.init_path("tiny.meta"))?;
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train"))?;
    let registry = SharedRegistry::new();
    for t in tasks {
        registry.deploy(t.adapter_key(), adapter.clone());
    }
    let builder = cfg(Server::builder("tiny")
        .manifest(manifest)
        .max_batch(4)
        .max_wait(Duration::from_millis(2)));
    let server = builder.build(meta, registry)?;
    Ok((server, v.vocab, v.seq))
}

fn jobs_for(tasks: &[GlueTask], vocab: usize, seq: usize, n: usize, seed: u64) -> Vec<(String, Vec<i32>)> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|i| {
            let task = tasks[i % tasks.len()];
            let gen = GlueGen::new(task, vocab, seq);
            let (tokens, _, _) = gen.example(&mut rng);
            (task.adapter_key().to_string(), tokens)
        })
        .collect()
}

#[test]
fn multi_worker_mixed_wave_zero_lost() {
    if !ready() {
        return;
    }
    // SST-2 and QNLI are pinned to DIFFERENT workers under FNV-1a % 2
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let (server, vocab, seq) = setup(&tasks, |b| b.workers(2)).unwrap();
    let client = server.client();
    assert_ne!(client.shard_for("SST-2"), client.shard_for("QNLI"));

    let jobs = jobs_for(&tasks, vocab, seq, 24, 1);
    let responses = submit_wave(&client, &jobs).unwrap();
    assert_eq!(responses.len(), 24, "zero lost responses");
    for (r, (task, _)) in responses.iter().zip(&jobs) {
        assert_eq!(&r.task, task);
        assert_eq!(r.worker, client.shard_for(task), "task stays on its shard");
        assert_eq!(r.logits.len(), 4); // padded n_cls
        assert!(r.logits.iter().all(|x| x.is_finite()));
        assert!(r.batch_size >= 1 && r.batch_size <= 4);
    }
    // per-worker AND aggregate accounting must line up
    let per_worker: Vec<u64> = server
        .worker_metrics()
        .iter()
        .map(|m| m.served.load(Ordering::Relaxed))
        .collect();
    assert_eq!(per_worker.len(), 2);
    assert!(per_worker.iter().all(|&s| s > 0), "both workers served: {per_worker:?}");
    let agg = server.metrics();
    assert_eq!(agg.served, 24);
    assert_eq!(per_worker.iter().sum::<u64>(), 24);
    assert!(agg.adapter_swaps >= 2);
    assert_eq!(agg.errors, 0);
    let report = server.metrics_report();
    assert!(report.contains("worker0") && report.contains("worker1"));
    server.shutdown().unwrap();
}

#[test]
fn pipeline_scheduler_serves_wave_and_reports_model() {
    if !ready() {
        return;
    }
    // same wave as the fixed batcher, but batch fills come from the
    // AIMC/PMCA cost model; every ticket must still resolve and the
    // pool must report modeled batch latency next to the measured one
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let v = Manifest::load(default_artifacts_dir())
        .unwrap()
        .variant("tiny")
        .unwrap()
        .clone();
    let (server, vocab, seq) = setup(&tasks, |b| {
        b.workers(2)
            .scheduler(SchedConfig::for_layer(v.d_model, v.d_model, v.rank))
    })
    .unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 24, 7);
    let responses = submit_wave(&client, &jobs).unwrap();
    assert_eq!(responses.len(), 24, "zero lost responses under the scheduler");
    for (r, (task, _)) in responses.iter().zip(&jobs) {
        assert_eq!(&r.task, task);
        assert!(r.logits.iter().all(|x| x.is_finite()));
    }
    let agg = server.metrics();
    assert_eq!(agg.served, 24);
    assert_eq!(agg.errors, 0);
    assert!(agg.modeled_p50_ms > 0.0, "modeled latency recorded: {agg:?}");
    assert!(server.metrics_report().contains("model_p50"));
    server.shutdown().unwrap();
}

#[test]
fn injected_batch_failures_still_resolve_every_ticket() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let (server, vocab, seq) = setup(&tasks, |b| b.workers(2).inject_batch_failure(2)).unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 16, 2);
    let pendings: Vec<Pending> = jobs
        .iter()
        .map(|(task, toks)| client.submit(task, toks).unwrap())
        .collect();
    let mut oks = 0u64;
    let mut errs = 0u64;
    for p in pendings {
        match p.wait() {
            Ok(r) => {
                assert!(r.logits.iter().all(|x| x.is_finite()));
                oks += 1;
            }
            Err(ServeError::Batch { detail, .. }) => {
                assert!(detail.contains("injected"));
                errs += 1;
            }
            Err(e) => panic!("unexpected error kind: {e}"),
        }
    }
    assert_eq!(oks + errs, 16, "every admitted ticket resolved");
    assert!(errs > 0, "fault injection fired");
    assert!(oks > 0, "healthy batches still served");
    assert_eq!(server.metrics().errors, errs);
    server.shutdown().unwrap();
}

#[test]
fn bounded_queue_backpressure_returns_overloaded() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    // one worker, 2 in-flight slots, and a batch deadline far enough out
    // that the queue cannot drain while we hammer it
    let (server, vocab, seq) = setup(&tasks, |b| {
        b.workers(1)
            .queue_depth(2)
            .max_batch(8)
            .max_wait(Duration::from_secs(2))
    })
    .unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 6, 3);
    let mut admitted = Vec::new();
    let mut overloaded = 0u64;
    for (task, toks) in &jobs {
        match client.submit(task, toks) {
            Ok(p) => admitted.push(p),
            Err(ServeError::Overloaded { worker, depth }) => {
                assert_eq!(worker, 0);
                assert_eq!(depth, 2);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error kind: {e}"),
        }
    }
    // a scheduler stall can let the deadline fire and free slots
    // mid-loop, so bound rather than pin the split
    assert!(admitted.len() >= 2, "at least queue_depth admissions");
    assert_eq!(overloaded, 6 - admitted.len() as u64);
    assert!(overloaded >= 1, "the bounded queue pushed back");
    assert_eq!(server.metrics().rejected, overloaded);
    for p in admitted {
        assert!(p.wait().is_ok(), "admitted requests still served");
    }
    // slots freed -> the try-again protocol succeeds
    let p = client
        .submit_with_retry(&jobs[0].0, &jobs[0].1, Duration::from_secs(10))
        .unwrap();
    assert!(p.wait().is_ok());
    server.shutdown().unwrap();
}

#[test]
fn concurrent_redeploy_is_version_monotonic() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    let (server, vocab, seq) = setup(&tasks, |b| b.workers(1)).unwrap();
    let client = server.client();
    let registry = server.registry().clone();
    let adapter = {
        let manifest = Manifest::load(default_artifacts_dir()).unwrap();
        checkpoint::load(manifest.init_path("tiny.step_cls_lora.train")).unwrap()
    };

    let redeployer = std::thread::spawn(move || {
        for _ in 0..5 {
            registry.deploy("SST-2", adapter.clone());
            std::thread::sleep(Duration::from_millis(3));
        }
    });
    let mut versions = Vec::new();
    for wave in 0..4 {
        let jobs = jobs_for(&tasks, vocab, seq, 8, 10 + wave);
        for r in submit_wave(&client, &jobs).unwrap() {
            versions.push(r.adapter_version);
        }
    }
    redeployer.join().unwrap();

    let final_version = server.registry().version("SST-2").unwrap();
    assert_eq!(final_version, 6, "1 initial + 5 redeploys");
    assert!(versions.iter().all(|&v| v >= 1 && v <= final_version));
    // single worker + single task => batches are FIFO, so the observed
    // version sequence never goes backwards
    assert!(
        versions.windows(2).all(|w| w[0] <= w[1]),
        "versions observed monotonically: {versions:?}"
    );
    // after the redeployer is done, traffic sees the final version
    let jobs = jobs_for(&tasks, vocab, seq, 4, 99);
    for r in submit_wave(&client, &jobs).unwrap() {
        assert_eq!(r.adapter_version, final_version);
    }
    server.shutdown().unwrap();
}

#[test]
fn shutdown_drains_all_pending_requests() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    // deadline far in the future: ONLY the drain path can release these
    let (server, vocab, seq) = setup(&tasks, |b| {
        b.max_batch(8).max_wait(Duration::from_secs(60))
    })
    .unwrap();
    let client = server.client();
    let jobs = jobs_for(&tasks, vocab, seq, 3, 4);
    let pendings: Vec<Pending> = jobs
        .iter()
        .map(|(task, toks)| client.submit(task, toks).unwrap())
        .collect();
    server.shutdown().unwrap();
    for p in pendings {
        let r = p.wait().expect("drained response");
        assert_eq!(r.task, "SST-2");
    }
    // surviving client handles are refused cleanly
    assert_eq!(
        client.submit("SST-2", &jobs[0].1).unwrap_err(),
        ServeError::ShuttingDown
    );
}

#[test]
fn typed_rejections_and_live_task_deploys() {
    if !ready() {
        return;
    }
    let (server, _, seq) = setup(&[GlueTask::Sst2], |b| b).unwrap();
    let client = server.client();
    assert!(matches!(
        client.submit("made-up-task", &vec![0; seq]).unwrap_err(),
        ServeError::UnknownTask { .. }
    ));
    assert_eq!(
        client.submit("SST-2", &vec![0; seq + 1]).unwrap_err(),
        ServeError::BadShape { got: seq + 1, want: seq }
    );
    // tasks deployed AFTER startup are immediately routable (the old
    // Router froze its task list at start)
    let manifest = Manifest::load(default_artifacts_dir()).unwrap();
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train")).unwrap();
    server.registry().deploy("QNLI", adapter);
    let v = manifest.variant("tiny").unwrap().clone();
    let mut rng = Pcg64::new(5);
    let (tokens, _, _) = GlueGen::new(GlueTask::Qnli, v.vocab, v.seq).example(&mut rng);
    let r = client.submit("QNLI", &tokens).unwrap().wait().unwrap();
    assert_eq!(r.task, "QNLI");
    server.shutdown().unwrap();
}

#[test]
fn builder_rejects_unknown_variant_and_graph() {
    if !ready() {
        return;
    }
    let manifest = Manifest::load(default_artifacts_dir()).unwrap();
    let meta = checkpoint::load(manifest.init_path("tiny.meta")).unwrap();
    let err = Server::builder("no-such-variant")
        .build(meta.clone(), SharedRegistry::new())
        .unwrap_err();
    assert!(matches!(err, ServeError::Init { .. }));
    let err = Server::builder("tiny")
        .graph("tiny/no_such_graph")
        .build(meta, SharedRegistry::new())
        .unwrap_err();
    assert!(matches!(err, ServeError::Init { .. }));
}
