//! Serving-stack integration: router → batcher → worker (PJRT) →
//! responses, with adapter hot-swaps mid-stream. Needs artifacts.

use std::time::Duration;

use ahwa_lora::config::manifest::default_artifacts_dir;
use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::model::checkpoint;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::server::{submit_wave, ServeConfig, Server};
use ahwa_lora::util::rng::Pcg64;

fn ready() -> bool {
    let ok = default_artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

fn setup(tasks: &[GlueTask]) -> anyhow::Result<(Server, usize, usize)> {
    let manifest = ahwa_lora::config::manifest::Manifest::load(default_artifacts_dir())?;
    let v = manifest.variant("tiny")?.clone();
    let meta = checkpoint::load(manifest.init_path("tiny.meta"))?;
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train"))?;
    let registry = SharedRegistry::new();
    for t in tasks {
        registry.deploy(t.adapter_key(), adapter.clone());
    }
    let mut cfg = ServeConfig::new("tiny");
    cfg.max_batch = 4;
    cfg.max_wait = Duration::from_millis(2);
    let server = Server::start(cfg, meta, registry)?;
    Ok((server, v.vocab, v.seq))
}

#[test]
fn serves_mixed_task_wave() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2, GlueTask::Qnli];
    let (server, vocab, seq) = setup(&tasks).unwrap();
    let mut rng = Pcg64::new(1);
    let mut jobs = Vec::new();
    for i in 0..24 {
        let task = tasks[i % 2];
        let gen = GlueGen::new(task, vocab, seq);
        let (tokens, _, _) = gen.example(&mut rng);
        jobs.push((task.adapter_key().to_string(), tokens));
    }
    let responses = submit_wave(&server.router, &jobs).unwrap();
    assert_eq!(responses.len(), 24);
    for (r, (task, _)) in responses.iter().zip(&jobs) {
        assert_eq!(&r.task, task);
        assert_eq!(r.logits.len(), 4); // padded n_cls
        assert!(r.logits.iter().all(|x| x.is_finite()));
        assert!(r.batch_size >= 1 && r.batch_size <= 4);
    }
    // both tasks served; swaps happened (mixed wave, single worker)
    assert!(server.metrics.adapter_swaps.load(std::sync::atomic::Ordering::Relaxed) >= 2);
    assert_eq!(server.metrics.served.load(std::sync::atomic::Ordering::Relaxed), 24);
    server.shutdown().unwrap();
}

#[test]
fn hot_swap_changes_served_version() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    let (server, vocab, seq) = setup(&tasks).unwrap();
    let gen = GlueGen::new(GlueTask::Sst2, vocab, seq);
    let mut rng = Pcg64::new(2);
    let (tokens, _, _) = gen.example(&mut rng);

    let jobs = vec![("SST-2".to_string(), tokens.clone())];
    let r1 = submit_wave(&server.router, &jobs).unwrap();
    assert_eq!(r1[0].adapter_version, 1);

    // re-deploy (the paper's on-chip adaptation to new user data)
    let manifest = ahwa_lora::config::manifest::Manifest::load(default_artifacts_dir()).unwrap();
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train")).unwrap();
    server.registry.deploy("SST-2", adapter);
    let r2 = submit_wave(&server.router, &jobs).unwrap();
    assert_eq!(r2[0].adapter_version, 2);
    server.shutdown().unwrap();
}

#[test]
fn rejects_unknown_task_and_bad_shape() {
    if !ready() {
        return;
    }
    let (server, _, seq) = setup(&[GlueTask::Sst2]).unwrap();
    assert!(server.router.submit("made-up-task", vec![0; seq]).is_err());
    assert!(server.router.submit("SST-2", vec![0; seq + 1]).is_err());
    server.shutdown().unwrap();
}

#[test]
fn shutdown_drains_pending_requests() {
    if !ready() {
        return;
    }
    let tasks = [GlueTask::Sst2];
    let (server, vocab, seq) = setup(&tasks).unwrap();
    let gen = GlueGen::new(GlueTask::Sst2, vocab, seq);
    let mut rng = Pcg64::new(3);
    // single request below max_batch: only served on deadline/drain
    let (tokens, _, _) = gen.example(&mut rng);
    let (_, rx) = server.router.submit("SST-2", tokens).unwrap();
    server.shutdown().unwrap();
    // the response must have been delivered before the worker exited
    let resp = rx.try_recv().expect("drained response");
    assert_eq!(resp.task, "SST-2");
}
