//! Backend-HAL conformance suite (`serve::hal`).
//!
//! Pinned:
//!
//! * **Default-backend equivalence.** A `SimPool` built on an explicit
//!   `PcmPjrt::default()` backend produces a bit-identical batch and
//!   swap trace to the builder default (no backend), and the backend's
//!   cost model IS the scheduler's latency table — the HAL introduces
//!   zero behavior change on the reference substrate (which is why the
//!   four existing conformance suites pass unmodified on it).
//! * **Heterogeneous routing.** On a mixed PCM + digital-reference
//!   pool, each task routes to the backend minimising modeled service
//!   plus tolerance-maintenance cost: tight tolerances leave the
//!   drifting substrate, relaxed ones stay on the fast one, and the
//!   routed assignment is strictly cheaper than a cost-blind
//!   round-robin placement of the same tasks.
//! * **Routing properties** (property tests over random cost tables):
//!   the decision is deterministic, stays in range, respects pins, and
//!   never places a task on a backend that cannot sustain its arrival
//!   rate while another can.
//! * **Hermetic serving.** A `DigitalRef` pool stands up a REAL
//!   `Server` (threads, channels, admission) with no artifacts and no
//!   XLA, serves deterministic logits, and a mixed pool routes
//!   requests through the backend cost models end to end.
//! * **Build validation.** Cross-config mistakes fail fast as typed
//!   `BuildError`s, before any manifest I/O — so they are pinned here
//!   without artifacts (the `--no-default-features` lean build
//!   compiles and runs every ungated test in this file).

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::Arc;
use std::time::Duration;

use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::hal::{route_one, route_tasks};
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    Backend, BackendProfile, BatchScheduler, BuildError, CoordConfig, CostModel, DecayModel,
    PcmPjrt, RefreshCoupling, SchedConfig, Server, TaskProfile,
};
use ahwa_lora::util::proptest::check;
use refresh_sim::SimPool;

const TASKS: [&str; 3] = ["t0", "t1", "t2"];
/// 3 trigger cycles on the builder default (`trigger_in` = 100 ms,
/// 500 µs arrivals).
const ROUNDS: usize = 600;
const IA: Duration = Duration::from_micros(500);

type BatchTrace = Vec<(usize, String, Duration, Duration, usize, u64)>;
type SwapTrace = Vec<(String, Duration, u64)>;

/// Drive the standard workload and return the full observable trace,
/// with instants rebased onto the pool's own epoch so traces from two
/// pools (two `VirtualClock`s) compare exactly.
fn drive(mut pool: SimPool) -> (BatchTrace, SwapTrace) {
    let t0 = pool.now();
    pool.run_rounds(ROUNDS, IA);
    pool.flush(IA);
    let batches = pool
        .batches
        .iter()
        .map(|b| {
            (
                b.worker,
                b.task.clone(),
                b.popped_at.saturating_duration_since(t0),
                b.done_at.saturating_duration_since(t0),
                b.fill,
                b.version,
            )
        })
        .collect();
    let swaps = pool
        .swaps
        .iter()
        .map(|s| (s.task.clone(), s.at.saturating_duration_since(t0), s.version))
        .collect();
    (batches, swaps)
}

#[test]
fn explicit_pcm_backend_is_behavior_identical_to_the_default_pool() {
    let base = || SimPool::builder().workers(2).tasks(&TASKS);
    let (batches, swaps) = drive(base().build());
    let (hal_batches, hal_swaps) = drive(base().backend(Arc::new(PcmPjrt::default())).build());
    assert!(!batches.is_empty(), "the trace exercised the serve path");
    assert!(!swaps.is_empty(), "the trace exercised the refresh path");
    assert_eq!(batches, hal_batches, "batch trace must be bit-identical");
    assert_eq!(swaps, hal_swaps, "swap trace must be bit-identical");
}

#[test]
fn pcm_cost_model_is_the_scheduler_latency_table() {
    let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
    let be = PcmPjrt::default();
    let adapted = be.adapt_sched(layer);
    assert_eq!(
        adapted.t_int_ns, layer.t_int_ns,
        "PcmPjrt::adapt_sched is the identity"
    );
    let cm = be.cost_model(&layer, refresh_sim::MAX_BATCH);
    let sched = BatchScheduler::new(layer, refresh_sim::MAX_BATCH, Duration::from_millis(5));
    for fill in 1..=refresh_sim::MAX_BATCH {
        assert_eq!(
            cm.batch_ns(fill),
            sched.modeled_batch_ns(fill),
            "placement and batch-close decisions diverged at fill {fill}"
        );
    }
}

#[test]
fn routing_decision_properties() {
    check("route_one: deterministic, in range, sustaining-first", 300, |g| {
        let n = g.usize_in(1, 4);
        let backends: Vec<BackendProfile> = (0..n)
            .map(|i| {
                let base = g.f64_in(50.0, 5_000.0);
                let table: Vec<f64> = (1..=4u32)
                    .map(|b| base * f64::from(b).powf(g.f64_in(0.5, 1.0)))
                    .collect();
                BackendProfile {
                    name: format!("b{i}"),
                    cost: CostModel::from_table(table),
                    drift: if g.bool() {
                        Some(DecayModel::analytic(PcmModel::default()))
                    } else {
                        None
                    },
                    refit_ns: g.f64_in(0.0, 1e7),
                }
            })
            .collect();
        let gap = g.f64_in(10.0, 1e7);
        let tol = g.f64_in(1e-4, 0.9);
        let picked = route_one(&backends, gap, tol);
        assert!(picked < n, "route stays in range");
        assert_eq!(picked, route_one(&backends, gap, tol), "decision is deterministic");
        if backends.iter().any(|b| b.cost.can_sustain(gap)) {
            assert!(
                backends[picked].cost.can_sustain(gap),
                "never a non-sustaining backend while another sustains"
            );
        }
        let pin = g.usize_in(0, n - 1);
        let tasks = vec![
            TaskProfile {
                task: "pinned".into(),
                tolerance: tol,
                interarrival_ns: gap,
                pinned: Some(pin),
            },
            TaskProfile {
                task: "free".into(),
                tolerance: tol,
                interarrival_ns: gap,
                pinned: None,
            },
        ];
        let routed = route_tasks(&backends, &tasks);
        assert_eq!(routed[0], pin, "pins override the cost decision");
        assert_eq!(routed[1], picked, "unpinned tasks follow route_one");
    });
}

#[test]
fn builder_validation_fails_fast_before_io() {
    // none of these configurations reach the manifest: every error
    // below is produced hermetically, with no artifacts on disk
    let coupled = SchedConfig::for_layer(128, 128, 8).coupling(RefreshCoupling::default());
    let err = Server::builder("any")
        .scheduler(coupled)
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert_eq!(err, BuildError::CouplingWithoutRefresh);

    let err = Server::builder("any")
        .coordination(CoordConfig::default())
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert_eq!(err, BuildError::CoordinationWithoutCoupling);

    let err = Server::builder("any")
        .workers(1)
        .backend(Arc::new(PcmPjrt::default()))
        .backend(Arc::new(PcmPjrt::default()))
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert!(
        matches!(&err, BuildError::Backends { detail } if detail.contains("at least one worker")),
        "2 backends cannot share 1 worker: {err}"
    );

    let err = Server::builder("any")
        .workers(2)
        .backend(Arc::new(PcmPjrt::default()))
        .backend(Arc::new(PcmPjrt::default()))
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert!(
        matches!(&err, BuildError::Backends { detail } if detail.contains("duplicate")),
        "backend names must be unique: {err}"
    );

    let err = Server::builder("any")
        .pin_task("task", 3)
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert!(
        matches!(&err, BuildError::Backends { detail } if detail.contains("pinned")),
        "pins must address a registered backend: {err}"
    );
}

#[cfg(feature = "digital-ref")]
mod digital {
    use super::*;
    use std::collections::BTreeMap;

    use ahwa_lora::config::manifest::{GraphSpec, HwDefaults, IoSpec, Manifest, Role, VariantCfg};
    use ahwa_lora::serve::hal::assignment_cost;
    use ahwa_lora::serve::{DigitalRef, FnRefitter, Refit, Refitter, RefreshConfig};
    use refresh_sim::adapter;

    #[test]
    fn drift_free_backend_never_refits_and_prices_the_slowdown() {
        let base = SimPool::builder().workers(2).tasks(&TASKS).build();
        let mut pool = SimPool::builder()
            .workers(2)
            .tasks(&TASKS)
            .backend(Arc::new(DigitalRef::default()))
            .build();
        pool.run_rounds(ROUNDS, IA);
        pool.flush(IA);
        assert_eq!(pool.served(), ROUNDS * TASKS.len(), "every request served");
        assert!(pool.swaps.is_empty(), "a drift-free substrate never triggers a refresh");
        for fill in 1..=refresh_sim::MAX_BATCH {
            assert!(
                pool.modeled_batch_ns(fill) > base.modeled_batch_ns(fill),
                "the digital slowdown must be priced into the worker schedulers (fill {fill})"
            );
        }
    }

    #[test]
    fn routed_placement_beats_cost_blind_round_robin() {
        let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
        let backends = vec![
            BackendProfile::of(&PcmPjrt::default(), &layer, 8),
            BackendProfile::of(&DigitalRef::default(), &layer, 8),
        ];
        // slow traffic: every backend sustains the rate, so the
        // decision is pure placement cost — tight tolerances pay a
        // huge PCM maintenance bill, relaxed ones only the digital
        // slowdown
        let tasks: Vec<TaskProfile> = (0..6)
            .map(|i| TaskProfile {
                task: format!("t{i}"),
                tolerance: if i % 2 == 0 { 1e-6 } else { 0.5 },
                interarrival_ns: 1e9,
                pinned: None,
            })
            .collect();
        let routed = route_tasks(&backends, &tasks);
        for (t, &b) in tasks.iter().zip(&routed) {
            let expect = usize::from(t.tolerance < 0.5);
            assert_eq!(b, expect, "task {} (tolerance {})", t.task, t.tolerance);
            for (other, profile) in backends.iter().enumerate() {
                assert!(
                    backends[b].placement_cost(t.interarrival_ns, t.tolerance)
                        <= profile.placement_cost(t.interarrival_ns, t.tolerance),
                    "task {} routed to {b} but backend {other} is cheaper",
                    t.task
                );
            }
        }
        // the cost-blind baseline: round-robin in task order, which
        // misplaces every task of this trace
        let naive: Vec<usize> = (0..tasks.len()).map(|i| i % backends.len()).collect();
        let routed_cost = assignment_cost(&backends, &tasks, &routed);
        let naive_cost = assignment_cost(&backends, &tasks, &naive);
        assert!(
            routed_cost < naive_cost,
            "cost-model routing ({routed_cost:.0} ns) must beat round-robin ({naive_cost:.0} ns)"
        );
    }

    /// Shapes-only manifest: enough for admission (variant + graph
    /// seq) and for the digital forward, with no files behind it.
    fn cls_manifest() -> Manifest {
        let variant = VariantCfg {
            name: "base".into(),
            kind: "encoder".into(),
            vocab: 100,
            seq: 16,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            d_emb: 128,
            n_cls: 3,
            rank: 8,
            lora_alpha: 16.0,
            train_batch: 8,
            eval_batch: 8,
        };
        let graph = GraphSpec {
            key: "base/fwd_cls".into(),
            kind: "fwd_cls".into(),
            variant: "base".into(),
            file: String::new(),
            inputs: vec![IoSpec {
                name: "data/tokens".into(),
                role: Role::Data,
                shape: vec![4, 16],
                dtype: "i32".into(),
            }],
            outputs: vec![IoSpec {
                name: "logits".into(),
                role: Role::Logits,
                shape: vec![4, 3],
                dtype: "f32".into(),
            }],
        };
        Manifest {
            root: std::path::PathBuf::from("hal-conformance-unused"),
            hw: HwDefaults {
                weight_noise: 0.0,
                adc_noise: 0.0,
                clip_sigma: 127.0,
                dac_bits: 8,
                adc_bits: 8,
                g_max_us: 25.0,
                t0_seconds: 20.0,
            },
            grpo_group: 1,
            variants: BTreeMap::from([("base".to_string(), variant)]),
            graphs: BTreeMap::from([("base/fwd_cls".to_string(), graph)]),
        }
    }

    #[test]
    fn digital_pool_serves_hermetically_with_deterministic_logits() {
        let registry = SharedRegistry::new();
        registry.deploy("task", adapter(1.0));
        let server = Server::builder("base")
            .manifest(cls_manifest())
            .workers(2)
            .backend(Arc::new(DigitalRef::default()))
            .build(ParamStore::default(), registry)
            .expect("a digital pool needs no artifacts");
        let client = server.client();
        let tokens: Vec<i32> = (0..16).collect();
        let a = client.submit("task", &tokens).unwrap().wait().unwrap();
        let b = client.submit("task", &tokens).unwrap().wait().unwrap();
        assert_eq!(a.logits.len(), 3, "one class-logit row");
        assert!(a.logits.iter().all(|v| v.is_finite()));
        assert_eq!(a.logits, b.logits, "the digital forward is deterministic");
        assert!(server.routing().is_empty(), "one backend: no router, hash placement");
        server.shutdown().expect("clean shutdown");
    }

    #[test]
    fn mixed_pool_routes_and_serves_through_backend_cost_models() {
        let registry = SharedRegistry::new();
        registry.deploy("tight", adapter(1.0));
        registry.deploy("relaxed", adapter(2.0));
        let refitter: Arc<dyn Refitter> = Arc::new(FnRefitter(
            |_: &str,
             current: &ParamStore,
             _: &ParamStore,
             budget: usize|
             -> anyhow::Result<Refit> {
                Ok(Refit {
                    params: current.clone(),
                    steps: budget,
                })
            },
        ));
        let refresh = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), refitter)
            .tolerance(0.5)
            .task_tolerance("tight", 1e-6);
        // a deliberately expensive PCM refit: keeping the tight task
        // inside tolerance on the drifting substrate dwarfs the
        // digital slowdown, so the cost model MUST move it — while
        // the relaxed task's once-in-an-epoch refresh keeps it on the
        // faster analog path
        let server = Server::builder("base")
            .manifest(cls_manifest())
            .workers(2)
            .backend(Arc::new(PcmPjrt::default().refit_ns(5.0e9)))
            .backend(Arc::new(DigitalRef::default()))
            .refresh(refresh)
            .build(ParamStore::default(), registry)
            .expect("a mixed pool builds without artifacts");
        assert_eq!(
            server.routing(),
            vec![("relaxed".to_string(), 0), ("tight".to_string(), 1)],
            "tight tolerance moves to the drift-free backend, relaxed stays on PCM"
        );
        let client = server.client();
        let tokens: Vec<i32> = (0..16).collect();
        let resp = client.submit("tight", &tokens).unwrap().wait().unwrap();
        assert_eq!(resp.worker, 1, "the digital backend owns worker span [1, 2)");
        assert_eq!(resp.logits.len(), 3);
        // worker 0 is a PCM+PJRT worker with no artifacts behind it:
        // its bring-up failure surfaces at shutdown — the digital span
        // served real traffic regardless, which is the point
        assert!(server.shutdown().is_err());
    }
}
