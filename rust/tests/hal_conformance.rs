//! Backend-HAL conformance suite (`serve::hal`).
//!
//! Pinned:
//!
//! * **Default-backend equivalence.** A `SimPool` built on an explicit
//!   `PcmPjrt::default()` backend produces a bit-identical batch and
//!   swap trace to the builder default (no backend), and the backend's
//!   cost model IS the scheduler's latency table — the HAL introduces
//!   zero behavior change on the reference substrate (which is why the
//!   four existing conformance suites pass unmodified on it).
//! * **Heterogeneous routing.** On a mixed PCM + digital-reference
//!   pool, each task routes to the backend minimising modeled service
//!   plus tolerance-maintenance cost: tight tolerances leave the
//!   drifting substrate, relaxed ones stay on the fast one, and the
//!   routed assignment is strictly cheaper than a cost-blind
//!   round-robin placement of the same tasks.
//! * **Routing properties** (property tests over random cost tables):
//!   the decision is deterministic, stays in range, respects pins, and
//!   never places a task on a backend that cannot sustain its arrival
//!   rate while another can; `assignment_cost` totals are valid for
//!   every routed assignment (its in-range precondition is pinned with
//!   a debug-build panic test).
//! * **Cadenced rebalance** (property tests over synthetic crossover
//!   geometries): under stationary traffic every applied move strictly
//!   improves the modeled cost, each task converges in the first few
//!   ticks and then the router goes silent; under adversarial
//!   regime-flapping traffic moves never exceed the per-tick budget,
//!   never regress cost, and consecutive moves are spaced by at least
//!   the cooldown. Idle retirement bounds the router maps under task
//!   churn.
//! * **Live migration.** On the routed `SimPool` virtual clock a
//!   rebalance move is exactly-once (no request dropped or
//!   double-served), nothing serves on the old span after the handoff,
//!   and the drift anchor (`deployed_at` / `trigger_at`) survives
//!   bit-identically — a migration is not a redeploy. The migrating
//!   freeze drains at the batch boundary and lifts at queue-empty, and
//!   the capacity tier re-prices page-in to the destination's deploy
//!   cost without evicting the resident adapter. The adaptive pool
//!   provably beats sticky routing on shifted traffic (modeled p99).
//! * **Hermetic serving.** A `DigitalRef` pool stands up a REAL
//!   `Server` (threads, channels, admission) with no artifacts and no
//!   XLA, serves deterministic logits, and a mixed pool routes
//!   requests through the backend cost models end to end.
//! * **Build validation.** Cross-config mistakes fail fast as typed
//!   `BuildError`s, before any manifest I/O — so they are pinned here
//!   without artifacts (the `--no-default-features` lean build
//!   compiles and runs every ungated test in this file).

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::hal::{assignment_cost, route_one, route_tasks};
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    drift_free, AdapterCache, Backend, BackendProfile, BatchScheduler, BuildError, CacheConfig,
    CacheLookup, Clock, CoordConfig, CostModel, DecayModel, Metrics, PcmPjrt, RebalanceConfig,
    RebalanceRunner, RefreshCoupling, Router, SchedConfig, Server, TaskProfile, VirtualClock,
};
use ahwa_lora::util::proptest::check;
use ahwa_lora::util::stats;
use refresh_sim::{adapter, gap_shifting_from, SimPool};

const TASKS: [&str; 3] = ["t0", "t1", "t2"];
/// 3 trigger cycles on the builder default (`trigger_in` = 100 ms,
/// 500 µs arrivals).
const ROUNDS: usize = 600;
const IA: Duration = Duration::from_micros(500);

type BatchTrace = Vec<(usize, String, Duration, Duration, usize, u64)>;
type SwapTrace = Vec<(String, Duration, u64)>;

/// Drive the standard workload and return the full observable trace,
/// with instants rebased onto the pool's own epoch so traces from two
/// pools (two `VirtualClock`s) compare exactly.
fn drive(mut pool: SimPool) -> (BatchTrace, SwapTrace) {
    let t0 = pool.now();
    pool.run_rounds(ROUNDS, IA);
    pool.flush(IA);
    let batches = pool
        .batches
        .iter()
        .map(|b| {
            (
                b.worker,
                b.task.clone(),
                b.popped_at.saturating_duration_since(t0),
                b.done_at.saturating_duration_since(t0),
                b.fill,
                b.version,
            )
        })
        .collect();
    let swaps = pool
        .swaps
        .iter()
        .map(|s| (s.task.clone(), s.at.saturating_duration_since(t0), s.version))
        .collect();
    (batches, swaps)
}

#[test]
fn explicit_pcm_backend_is_behavior_identical_to_the_default_pool() {
    let base = || SimPool::builder().workers(2).tasks(&TASKS);
    let (batches, swaps) = drive(base().build());
    let (hal_batches, hal_swaps) = drive(base().backend(Arc::new(PcmPjrt::default())).build());
    assert!(!batches.is_empty(), "the trace exercised the serve path");
    assert!(!swaps.is_empty(), "the trace exercised the refresh path");
    assert_eq!(batches, hal_batches, "batch trace must be bit-identical");
    assert_eq!(swaps, hal_swaps, "swap trace must be bit-identical");
}

#[test]
fn pcm_cost_model_is_the_scheduler_latency_table() {
    let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
    let be = PcmPjrt::default();
    let adapted = be.adapt_sched(layer);
    assert_eq!(
        adapted.t_int_ns, layer.t_int_ns,
        "PcmPjrt::adapt_sched is the identity"
    );
    let cm = be.cost_model(&layer, refresh_sim::MAX_BATCH);
    let sched = BatchScheduler::new(layer, refresh_sim::MAX_BATCH, Duration::from_millis(5));
    for fill in 1..=refresh_sim::MAX_BATCH {
        assert_eq!(
            cm.batch_ns(fill),
            sched.modeled_batch_ns(fill),
            "placement and batch-close decisions diverged at fill {fill}"
        );
    }
}

#[test]
fn routing_decision_properties() {
    check("route_one: deterministic, in range, sustaining-first", 300, |g| {
        let n = g.usize_in(1, 4);
        let backends: Vec<BackendProfile> = (0..n)
            .map(|i| {
                let base = g.f64_in(50.0, 5_000.0);
                let table: Vec<f64> = (1..=4u32)
                    .map(|b| base * f64::from(b).powf(g.f64_in(0.5, 1.0)))
                    .collect();
                BackendProfile {
                    name: format!("b{i}"),
                    cost: CostModel::from_table(table),
                    drift: if g.bool() {
                        Some(DecayModel::analytic(PcmModel::default()))
                    } else {
                        None
                    },
                    refit_ns: g.f64_in(0.0, 1e7),
                    deploy_latency: Duration::from_micros(g.usize_in(10, 2000) as u64),
                }
            })
            .collect();
        let gap = g.f64_in(10.0, 1e7);
        let tol = g.f64_in(1e-4, 0.9);
        let picked = route_one(&backends, gap, tol);
        assert!(picked < n, "route stays in range");
        assert_eq!(picked, route_one(&backends, gap, tol), "decision is deterministic");
        if backends.iter().any(|b| b.cost.can_sustain(gap)) {
            assert!(
                backends[picked].cost.can_sustain(gap),
                "never a non-sustaining backend while another sustains"
            );
        }
        let pin = g.usize_in(0, n - 1);
        let tasks = vec![
            TaskProfile {
                task: "pinned".into(),
                tolerance: tol,
                interarrival_ns: gap,
                pinned: Some(pin),
            },
            TaskProfile {
                task: "free".into(),
                tolerance: tol,
                interarrival_ns: gap,
                pinned: None,
            },
        ];
        let routed = route_tasks(&backends, &tasks);
        assert_eq!(routed[0], pin, "pins override the cost decision");
        assert_eq!(routed[1], picked, "unpinned tasks follow route_one");
        // every assignment route_tasks emits satisfies assignment_cost's
        // documented in-range precondition, and the total it prices is a
        // valid, deterministic cost
        assert!(routed.iter().all(|&b| b < n), "route_tasks emits only valid backend indices");
        let cost = assignment_cost(&backends, &tasks, &routed);
        assert!(!cost.is_nan() && cost >= 0.0, "assignment cost is a valid total: {cost}");
        assert_eq!(
            cost,
            assignment_cost(&backends, &tasks, &routed),
            "assignment cost is deterministic"
        );
    });
}

/// `assignment_cost`'s precondition (every index in range) is a
/// `debug_assert` — out-of-range input must panic in debug builds
/// rather than silently clamp.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "assignment_cost: backend index")]
fn assignment_cost_rejects_out_of_range_backends_in_debug() {
    let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
    let backends = vec![BackendProfile::of(
        &PcmPjrt::default(),
        &layer,
        refresh_sim::MAX_BATCH,
    )];
    let tasks = vec![TaskProfile {
        task: "t".into(),
        tolerance: 0.05,
        interarrival_ns: 1e6,
        pinned: None,
    }];
    assignment_cost(&backends, &tasks, &[1]);
}

#[test]
fn builder_validation_fails_fast_before_io() {
    // none of these configurations reach the manifest: every error
    // below is produced hermetically, with no artifacts on disk
    let coupled = SchedConfig::for_layer(128, 128, 8).coupling(RefreshCoupling::default());
    let err = Server::builder("any")
        .scheduler(coupled)
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert_eq!(err, BuildError::CouplingWithoutRefresh);

    let err = Server::builder("any")
        .coordination(CoordConfig::default())
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert_eq!(err, BuildError::CoordinationWithoutCoupling);

    let err = Server::builder("any")
        .workers(1)
        .backend(Arc::new(PcmPjrt::default()))
        .backend(Arc::new(PcmPjrt::default()))
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert!(
        matches!(&err, BuildError::Backends { detail } if detail.contains("at least one worker")),
        "2 backends cannot share 1 worker: {err}"
    );

    let err = Server::builder("any")
        .workers(2)
        .backend(Arc::new(PcmPjrt::default()))
        .backend(Arc::new(PcmPjrt::default()))
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert!(
        matches!(&err, BuildError::Backends { detail } if detail.contains("duplicate")),
        "backend names must be unique: {err}"
    );

    let err = Server::builder("any")
        .pin_task("task", 3)
        .build(ParamStore::default(), SharedRegistry::new())
        .unwrap_err();
    assert!(
        matches!(&err, BuildError::Backends { detail } if detail.contains("pinned")),
        "pins must address a registered backend: {err}"
    );
}

// ---------------------------------------------------------------------------
// Cadenced rebalance: hysteresis + cooldown property tests
// ---------------------------------------------------------------------------

/// Synthetic two-backend crossover geometry: a drifting "analog"
/// backend with a sublinear batch table against a `mult`× slower
/// drift-free "digital" one. `refit_ns` prices the analog
/// tolerance-maintenance bill, so the crossover gap — below it analog
/// wins, above it digital does — is set by the generator, not
/// hard-coded against any real cost table.
fn crossover_profiles(base: f64, mult: f64, refit_ns: f64) -> Vec<BackendProfile> {
    let table: Vec<f64> = (1..=4u32).map(|b| base * f64::from(b).powf(0.7)).collect();
    vec![
        BackendProfile {
            name: "analog".into(),
            cost: CostModel::from_table(table.clone()),
            drift: Some(DecayModel::analytic(PcmModel::default())),
            refit_ns,
            deploy_latency: Duration::from_nanos(400),
        },
        BackendProfile {
            name: "digital".into(),
            cost: CostModel::from_table(table.iter().map(|c| c * mult).collect()),
            drift: None,
            refit_ns: 0.0,
            deploy_latency: Duration::from_nanos(120),
        },
    ]
}

/// Two-span router over `profiles` on a virtual clock — the pure
/// routing-state harness the property tests drive without a worker
/// pool behind it.
fn synthetic_router(
    profiles: Vec<BackendProfile>,
    pins: BTreeMap<String, usize>,
    clock: Arc<VirtualClock>,
) -> Router {
    Router::new(
        profiles,
        vec![(0, 1), (1, 2)],
        0.05,
        BTreeMap::new(),
        pins,
        clock as Arc<dyn Clock>,
    )
}

#[test]
fn hysteresis_stationary_traffic_converges_then_goes_quiet() {
    check("rebalance: converge and go silent", 25, |g| {
        let base = g.f64_in(80.0, 400.0);
        let mult = g.f64_in(2.0, 6.0);
        let h = g.f64_in(0.25, 2.0);
        let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
        let refit = g.f64_in(0.001, 0.3) * (mult - 1.0) * age * 1e9;
        let profiles = crossover_profiles(base, mult, refit);
        // a gap where digital wins by at least 2× the hysteresis bar
        // (saving over the 512-arrival cooldown vs h × deploy(digital))
        let need = h * 120.0 * 2.0 / 512.0;
        let gap = gap_shifting_from(&profiles, 0, 0.05, need).expect("crossover gap exists");
        let ia_ns = gap.ceil();
        assert_eq!(route_one(&profiles, ia_ns, 0.05), 1, "still shifted at the integer gap");
        assert!(
            profiles[0].placement_cost(ia_ns, 0.05) - profiles[1].placement_cost(ia_ns, 0.05)
                > need,
            "saving still clears the bar at the integer gap"
        );
        let ia = Duration::from_nanos(ia_ns as u64);

        let clock = Arc::new(VirtualClock::new());
        let pins = BTreeMap::from([("pinned".to_string(), 0usize)]);
        let router = synthetic_router(profiles, pins, clock.clone());
        let tasks = ["a", "b", "c", "pinned"];
        for t in tasks {
            assert_eq!(router.backend_of(t), 0, "cold placement lands on analog");
        }
        let cfg = RebalanceConfig::new()
            .hysteresis(h)
            .cooldown(Duration::from_nanos((ia_ns * 512.0) as u64))
            .max_moves_per_tick(2)
            .idle_retire(None);

        let mut move_round: BTreeMap<String, usize> = BTreeMap::new();
        for round in 0..90 {
            clock.advance(ia);
            let now = clock.now();
            for t in tasks {
                router.note_arrival(t, now);
            }
            let moves = router.rebalance_with(&cfg, now);
            assert!(moves.len() <= 2, "per-tick move budget respected");
            for mv in moves {
                assert_ne!(mv.task, "pinned", "pins never migrate");
                assert_eq!((mv.from, mv.to), (0, 1), "moves follow the crossover");
                assert!(mv.cost_to < mv.cost_from, "every move strictly improves");
                assert!(
                    move_round.insert(mv.task.clone(), round).is_none(),
                    "stationary traffic: one move per task, then silence ({})",
                    mv.task
                );
            }
        }
        for t in ["a", "b", "c"] {
            let round = move_round.get(t).copied().expect("every free task converged");
            assert!(round < 8, "convergence happens in the first ticks, not eventually");
            assert_eq!(router.backend_of(t), 1);
        }
        assert_eq!(router.backend_of("pinned"), 0, "the pin held through 90 ticks");
        assert_eq!(move_round.len(), 3, "exactly the three free tasks moved");
    });
}

#[test]
fn cooldown_spacing_holds_under_regime_flapping_traffic() {
    check("rebalance: cooldown under flapping", 20, |g| {
        let base = g.f64_in(100.0, 300.0);
        let mult = g.f64_in(2.5, 3.5);
        let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
        let refit = g.f64_in(0.01, 0.05) * (mult - 1.0) * age * 1e9;
        let profiles = crossover_profiles(base, mult, refit);
        let hi = gap_shifting_from(&profiles, 0, 0.05, 3.5 * base)
            .expect("crossover gap exists")
            .ceil();
        assert_eq!(route_one(&profiles, hi, 0.05), 1, "slow regime routes digital");
        let lo = 100.0;
        assert_eq!(route_one(&profiles, lo, 0.05), 0, "fast regime routes analog");
        let cooldown = Duration::from_nanos((20.0 * hi) as u64);

        let clock = Arc::new(VirtualClock::new());
        let router = synthetic_router(profiles, BTreeMap::new(), clock.clone());
        assert_eq!(router.backend_of("flap"), 0);
        let cfg = RebalanceConfig::new()
            .hysteresis(0.0)
            .cooldown(cooldown)
            .max_moves_per_tick(1)
            .idle_retire(None);

        // adversarial flapping: alternate slow and fast half-cycles so
        // the modeled optimum keeps switching sides
        let mut move_at: Vec<Instant> = Vec::new();
        for _cycle in 0..10 {
            for &gap_ns in &[hi, lo] {
                let gap = Duration::from_nanos(gap_ns as u64);
                for _ in 0..14 {
                    clock.advance(gap);
                    let now = clock.now();
                    router.note_arrival("flap", now);
                    let moves = router.rebalance_with(&cfg, now);
                    assert!(moves.len() <= 1, "per-tick budget holds while flapping");
                    for mv in moves {
                        assert!(mv.cost_to < mv.cost_from, "flapping never regresses cost");
                        move_at.push(now);
                    }
                }
            }
        }
        assert!(
            move_at.len() >= 2,
            "the flapping traffic drove at least one migration each way"
        );
        for w in move_at.windows(2) {
            assert!(
                w[1].duration_since(w[0]) >= cooldown,
                "consecutive moves of one task are spaced by the cooldown"
            );
        }
    });
}

#[test]
fn idle_retirement_bounds_router_maps_under_task_churn() {
    let clock = Arc::new(VirtualClock::new());
    let backends: Vec<Arc<dyn Backend>> = vec![
        Arc::new(PcmPjrt::default()),
        Arc::new(PcmPjrt::conservative()),
    ];
    let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
    let profiles: Vec<BackendProfile> = backends
        .iter()
        .map(|b| BackendProfile::of(b.as_ref(), &layer, refresh_sim::MAX_BATCH))
        .collect();
    let router = Arc::new(Router::new(
        profiles,
        vec![(0, 1), (1, 2)],
        0.05,
        BTreeMap::new(),
        BTreeMap::new(),
        clock.clone() as Arc<dyn Clock>,
    ));
    let metrics = Arc::new(Metrics::default());
    let runner = RebalanceRunner::new(
        RebalanceConfig::new().idle_retire(Some(Duration::from_millis(10))),
        router.clone(),
        backends,
    )
    .with_metrics(metrics.clone());

    let persistent = ["p0", "p1", "p2", "p3"];
    for i in 0..400usize {
        clock.advance(Duration::from_millis(1));
        let now = clock.now();
        // a fresh one-shot task every round — the unbounded-growth
        // regression: before idle retirement these entries lived forever
        let churn = format!("churn{i}");
        router.note_arrival(&churn, now);
        router.backend_of(&churn);
        for t in persistent {
            router.note_arrival(t, now);
            router.backend_of(t);
        }
        runner.tick(now);
        let (table, arrivals) = router.map_sizes();
        assert!(
            table <= 16 && arrivals <= 16,
            "router maps stay bounded under churn (round {i}: table {table}, arrivals {arrivals})"
        );
    }
    assert!(
        metrics.tasks_retired.load(Ordering::Relaxed) >= 380,
        "nearly every one-shot task was retired"
    );
    for t in persistent {
        let placed = router.assignments().iter().any(|(task, _)| task == t);
        assert!(placed, "persistent task {t} survived retirement");
    }
}

// ---------------------------------------------------------------------------
// Live span migration on the routed SimPool virtual clock
// ---------------------------------------------------------------------------

/// The ungated PCM pair whose service/maintenance trade flips with
/// arrival rate — a fast substrate with an expensive refit against a
/// 4× slower one that refits for free — plus a measured gap provably
/// past the crossover. Returns `(backends, profiles, cold, dest, ia)`:
/// tasks cold-place on `cold` and the hysteresis gate provably opens
/// toward `dest` at inter-arrival `ia` (the saving over
/// `cooldown_arrivals` arrivals clears `hysteresis ×` the
/// destination's deploy latency with 2× margin).
fn pcm_shift_geometry(
    hysteresis: f64,
    cooldown_arrivals: f64,
) -> (Vec<Arc<dyn Backend>>, Vec<BackendProfile>, usize, usize, Duration) {
    let fast: Arc<dyn Backend> = Arc::new(PcmPjrt::default().refit_ns(5.0e9));
    let lean: Arc<dyn Backend> = Arc::new(
        PcmPjrt::default()
            .named("pcm-lean")
            .t_int_scale(4.0)
            .refit_ns(0.0)
            .deploy_latency(Duration::from_micros(100)),
    );
    let backends = vec![fast, lean];
    let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
    let profiles: Vec<BackendProfile> = backends
        .iter()
        .map(|b| BackendProfile::of(b.as_ref(), &layer, refresh_sim::MAX_BATCH))
        .collect();
    let cold = route_one(&profiles, f64::INFINITY, 0.05);
    let dest = 1 - cold;
    let need =
        hysteresis * profiles[dest].deploy_latency.as_nanos() as f64 * 2.0 / cooldown_arrivals;
    let gap = gap_shifting_from(&profiles, cold, 0.05, need).expect("crossover gap exists");
    let ia_ns = gap.ceil();
    assert_eq!(
        route_one(&profiles, ia_ns, 0.05),
        dest,
        "still shifted at the integer gap"
    );
    assert!(
        profiles[cold].placement_cost(ia_ns, 0.05) - profiles[dest].placement_cost(ia_ns, 0.05)
            > need,
        "saving still clears the hysteresis bar at the integer gap"
    );
    (backends, profiles, cold, dest, Duration::from_nanos(ia_ns as u64))
}

#[test]
fn migrating_freeze_drains_at_batch_boundary_and_lifts_at_queue_empty() {
    let mut pool = SimPool::builder().workers(1).tasks(&["t0"]).build();
    pool.advance(IA);
    pool.push("t0");
    pool.handle.set_migrating("t0", true);
    let drains_before = pool.drains;
    pool.drain();
    assert_eq!(pool.pending(), 0, "the freeze drains the queue, it does not park it");
    assert!(
        pool.drains > drains_before,
        "a migrating task's close is pressure-shaped (drain), not a deadline wait"
    );
    assert!(
        !pool.handle.is_migrating("t0"),
        "the freeze lifts at queue-empty, exactly the worker-loop discipline"
    );
}

#[test]
fn live_migration_is_exactly_once_and_preserves_the_drift_anchor() {
    let (backends, _, cold, dest, ia) = pcm_shift_geometry(0.5, 600.0);
    let tasks = ["m0", "m1", "m2"];
    let mut pool = SimPool::builder()
        .workers(2)
        .tasks(&tasks)
        .backends(&backends)
        .rebalance(
            RebalanceConfig::new()
                .hysteresis(0.5)
                .cooldown(ia * 600)
                .idle_retire(None),
        )
        .trigger_in(Duration::from_secs(1_000_000_000))
        .build();
    let router = pool.router.clone().expect("routed pool");
    let anchors: Vec<_> = tasks
        .iter()
        .map(|t| (pool.handle.deployed_at(t), pool.handle.trigger_at(t)))
        .collect();
    assert!(anchors.iter().all(|(d, _)| d.is_some()), "deployments tracked");

    pool.run_rounds(40, ia);
    pool.flush(ia);

    // exactly-once: every enqueued request served exactly once
    assert_eq!(pool.served(), 120, "40 rounds × 3 tasks, nothing dropped or doubled");
    assert_eq!(pool.lat_ns.len(), 120);
    // every task crossed once, under the measured shifted traffic
    assert_eq!(pool.moves.len(), 3, "one move per task");
    let mut moved: Vec<&str> = pool.moves.iter().map(|(_, m)| m.task.as_str()).collect();
    moved.sort_unstable();
    assert_eq!(moved, tasks);
    for (_, mv) in &pool.moves {
        assert_eq!((mv.from, mv.to), (cold, dest));
        assert!(mv.cost_to < mv.cost_from, "every applied move strictly improves");
    }
    // nothing serves on the old span after its task's handoff
    let (span_start, span_end) = router.ranges()[dest];
    for b in &pool.batches {
        let moved_at = pool
            .moves
            .iter()
            .find(|(_, m)| m.task == b.task)
            .map(|&(at, _)| at)
            .expect("every task moved");
        if b.popped_at > moved_at {
            assert!(
                b.worker >= span_start && b.worker < span_end,
                "task {} served on worker {} after its move off span {cold}",
                b.task,
                b.worker
            );
        }
    }
    // a migration is not a redeploy: no refresh fired, and both drift
    // anchors survive bit-identically through freeze → carry → flip
    assert!(pool.swaps.is_empty(), "no refresh during the migration window");
    for (t, (deployed, trigger)) in tasks.iter().zip(&anchors) {
        assert_eq!(pool.handle.deployed_at(t), *deployed, "deployed_at preserved for {t}");
        assert_eq!(pool.handle.trigger_at(t), *trigger, "trigger_at preserved for {t}");
    }
    // the EWMA the move was planned against is the exact arrival gap
    for t in tasks {
        let ewma = router.arrival_ewma_ns(t).expect("measured");
        let ia_ns = ia.as_nanos() as f64;
        assert!((ewma - ia_ns).abs() <= 1e-9 * ia_ns, "constant gaps → exact EWMA");
    }
}

#[test]
fn migration_reprices_page_in_and_keeps_residency() {
    let (backends, profiles, cold, dest, ia) = pcm_shift_geometry(1.0, 64.0);
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    registry.deploy("t0", adapter(1.0));
    let metrics = Arc::new(Metrics::default());
    let cache = AdapterCache::new(
        CacheConfig::new(4).load_latency(Duration::from_micros(777)),
        registry.clone(),
        clock.clone() as Arc<dyn Clock>,
        metrics.clone(),
    );
    let router = Arc::new(Router::new(
        profiles,
        vec![(0, 1), (1, 2)],
        0.05,
        BTreeMap::new(),
        BTreeMap::new(),
        clock.clone() as Arc<dyn Clock>,
    ));
    assert_eq!(router.backend_of("t0"), cold, "cold placement");
    let runner = RebalanceRunner::new(
        RebalanceConfig::new()
            .hysteresis(1.0)
            .cooldown(ia * 64)
            .idle_retire(None),
        router.clone(),
        backends.clone(),
    )
    .with_cache(cache.clone())
    .with_metrics(metrics.clone());

    // page the adapter in at the configured (pre-migration) latency
    match cache.lookup("t0", clock.now(), 1) {
        CacheLookup::Hit | CacheLookup::Loading { .. } | CacheLookup::Queued { .. } => {}
        CacheLookup::Shed | CacheLookup::Unknown => panic!("deployed task must be admissible"),
    }
    clock.advance(Duration::from_secs(1));
    cache.poll(clock.now());
    assert!(cache.is_resident("t0"));
    assert_eq!(cache.load_latency_for("t0"), Duration::from_micros(777));

    let mut moves = Vec::new();
    for _ in 0..4 {
        clock.advance(ia);
        router.note_arrival("t0", clock.now());
        moves.extend(runner.tick(clock.now()));
    }
    assert_eq!(moves.len(), 1, "the shifted traffic drove exactly one move");
    assert_eq!((moves[0].from, moves[0].to), (cold, dest));
    assert_eq!(metrics.rebalance_moves.load(Ordering::Relaxed), 1);
    // residency is task-keyed: the move re-prices future page-ins to
    // the destination's deploy cost WITHOUT evicting the hot adapter
    assert!(cache.is_resident("t0"), "migration must not evict the resident adapter");
    assert_eq!(
        cache.load_latency_for("t0"),
        backends[dest].deploy_latency(),
        "page-in now costs the destination substrate's deploy latency"
    );
    assert_ne!(cache.load_latency_for("t0"), Duration::from_micros(777));
}

#[test]
fn adaptive_rebalance_beats_sticky_routing_on_shifted_traffic() {
    let run = |adaptive: bool| {
        let (backends, _, _, _, ia) = pcm_shift_geometry(0.5, 600.0);
        let mut b = SimPool::builder()
            .workers(2)
            .tasks(&["s0", "s1", "s2"])
            .backends(&backends)
            .trigger_in(Duration::from_secs(1_000_000_000));
        if adaptive {
            b = b.rebalance(
                RebalanceConfig::new()
                    .hysteresis(0.5)
                    .cooldown(ia * 600)
                    .idle_retire(None),
            );
        }
        let mut pool = b.build();
        // warmup: seed the EWMAs (and let the adaptive pool converge),
        // then measure a clean window
        pool.run_rounds(3, ia);
        pool.modeled_cost_ns.clear();
        pool.run_rounds(57, ia);
        pool.flush(ia);
        assert_eq!(pool.lat_ns.len(), 180, "every request served");
        pool
    };
    let adaptive = run(true);
    let sticky = run(false);
    assert!(!adaptive.moves.is_empty(), "the adaptive pool migrated");
    assert!(sticky.moves.is_empty(), "the sticky pool never moves");
    let (pa, ps) = (
        stats::percentile(&adaptive.modeled_cost_ns, 99.0),
        stats::percentile(&sticky.modeled_cost_ns, 99.0),
    );
    assert!(
        pa < ps,
        "adaptive modeled p99 ({pa:.0} ns) must beat sticky ({ps:.0} ns) on shifted traffic"
    );
    assert!(
        stats::mean(&adaptive.modeled_cost_ns) < stats::mean(&sticky.modeled_cost_ns),
        "and the mean moves the same way"
    );
}

// ---------------------------------------------------------------------------
// DigitalRef numerics knobs: drift-age separation (ungated slice)
// ---------------------------------------------------------------------------

#[test]
fn analog_profiles_separate_by_drift_age_and_the_digital_reference_is_drift_free() {
    let def = PcmPjrt::default().drift_model().expect("default PCM drifts");
    let cons = PcmPjrt::conservative().drift_model().expect("conservative PCM drifts");
    let digital = drift_free();
    let ages = [120.0, 1.2e3, 1.2e4, 1.2e5];
    let (mut prev_d, mut prev_c) = (0.0, 0.0);
    for &age in &ages {
        let d = def.predicted_decay(age);
        let c = cons.predicted_decay(age);
        assert_eq!(digital.predicted_decay(age), 0.0, "ideal substrate never decays");
        assert!(d > 0.0 && c > 0.0, "both analog substrates decay at age {age}");
        assert!(c < d, "the conservative profile decays slower at age {age}");
        assert!(d >= prev_d && c >= prev_c, "decay is monotone in age");
        (prev_d, prev_c) = (d, c);
    }
    // same tolerance → later trigger on the conservative substrate:
    // the separation the router's maintenance term prices
    let tol = def.predicted_decay(1000.0);
    let (td, tc) = (def.trigger_age(tol), cons.trigger_age(tol));
    assert!(td.is_finite() && td > 0.0, "the default substrate triggers");
    assert!(tc > td, "the conservative substrate triggers later at tolerance {tol}");
    assert!(
        digital.trigger_age(tol).is_infinite(),
        "the drift-free reference never triggers"
    );
}

#[cfg(feature = "digital-ref")]
mod digital {
    use super::*;
    use std::collections::BTreeMap;

    use ahwa_lora::config::manifest::{GraphSpec, HwDefaults, IoSpec, Manifest, Role, VariantCfg};
    use ahwa_lora::serve::{DigitalRef, Forward, FnRefitter, Refit, Refitter, RefreshConfig};

    #[test]
    fn drift_free_backend_never_refits_and_prices_the_slowdown() {
        let base = SimPool::builder().workers(2).tasks(&TASKS).build();
        let mut pool = SimPool::builder()
            .workers(2)
            .tasks(&TASKS)
            .backend(Arc::new(DigitalRef::default()))
            .build();
        pool.run_rounds(ROUNDS, IA);
        pool.flush(IA);
        assert_eq!(pool.served(), ROUNDS * TASKS.len(), "every request served");
        assert!(pool.swaps.is_empty(), "a drift-free substrate never triggers a refresh");
        for fill in 1..=refresh_sim::MAX_BATCH {
            assert!(
                pool.modeled_batch_ns(fill) > base.modeled_batch_ns(fill),
                "the digital slowdown must be priced into the worker schedulers (fill {fill})"
            );
        }
    }

    #[test]
    fn routed_placement_beats_cost_blind_round_robin() {
        let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
        let backends = vec![
            BackendProfile::of(&PcmPjrt::default(), &layer, 8),
            BackendProfile::of(&DigitalRef::default(), &layer, 8),
        ];
        // slow traffic: every backend sustains the rate, so the
        // decision is pure placement cost — tight tolerances pay a
        // huge PCM maintenance bill, relaxed ones only the digital
        // slowdown
        let tasks: Vec<TaskProfile> = (0..6)
            .map(|i| TaskProfile {
                task: format!("t{i}"),
                tolerance: if i % 2 == 0 { 1e-6 } else { 0.5 },
                interarrival_ns: 1e9,
                pinned: None,
            })
            .collect();
        let routed = route_tasks(&backends, &tasks);
        for (t, &b) in tasks.iter().zip(&routed) {
            let expect = usize::from(t.tolerance < 0.5);
            assert_eq!(b, expect, "task {} (tolerance {})", t.task, t.tolerance);
            for (other, profile) in backends.iter().enumerate() {
                assert!(
                    backends[b].placement_cost(t.interarrival_ns, t.tolerance)
                        <= profile.placement_cost(t.interarrival_ns, t.tolerance),
                    "task {} routed to {b} but backend {other} is cheaper",
                    t.task
                );
            }
        }
        // the cost-blind baseline: round-robin in task order, which
        // misplaces every task of this trace
        let naive: Vec<usize> = (0..tasks.len()).map(|i| i % backends.len()).collect();
        let routed_cost = assignment_cost(&backends, &tasks, &routed);
        let naive_cost = assignment_cost(&backends, &tasks, &naive);
        assert!(
            routed_cost < naive_cost,
            "cost-model routing ({routed_cost:.0} ns) must beat round-robin ({naive_cost:.0} ns)"
        );
    }

    /// Shapes-only manifest: enough for admission (variant + graph
    /// seq) and for the digital forward, with no files behind it.
    fn cls_manifest() -> Manifest {
        let variant = VariantCfg {
            name: "base".into(),
            kind: "encoder".into(),
            vocab: 100,
            seq: 16,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            d_emb: 128,
            n_cls: 3,
            rank: 8,
            lora_alpha: 16.0,
            train_batch: 8,
            eval_batch: 8,
        };
        let graph = GraphSpec {
            key: "base/fwd_cls".into(),
            kind: "fwd_cls".into(),
            variant: "base".into(),
            file: String::new(),
            inputs: vec![IoSpec {
                name: "data/tokens".into(),
                role: Role::Data,
                shape: vec![4, 16],
                dtype: "i32".into(),
            }],
            outputs: vec![IoSpec {
                name: "logits".into(),
                role: Role::Logits,
                shape: vec![4, 3],
                dtype: "f32".into(),
            }],
        };
        Manifest {
            root: std::path::PathBuf::from("hal-conformance-unused"),
            hw: HwDefaults {
                weight_noise: 0.0,
                adc_noise: 0.0,
                clip_sigma: 127.0,
                dac_bits: 8,
                adc_bits: 8,
                g_max_us: 25.0,
                t0_seconds: 20.0,
            },
            grpo_group: 1,
            variants: BTreeMap::from([("base".to_string(), variant)]),
            graphs: BTreeMap::from([("base/fwd_cls".to_string(), graph)]),
        }
    }

    #[test]
    fn digital_pool_serves_hermetically_with_deterministic_logits() {
        let registry = SharedRegistry::new();
        registry.deploy("task", adapter(1.0));
        let server = Server::builder("base")
            .manifest(cls_manifest())
            .workers(2)
            .backend(Arc::new(DigitalRef::default()))
            .build(ParamStore::default(), registry)
            .expect("a digital pool needs no artifacts");
        let client = server.client();
        let tokens: Vec<i32> = (0..16).collect();
        let a = client.submit("task", &tokens).unwrap().wait().unwrap();
        let b = client.submit("task", &tokens).unwrap().wait().unwrap();
        assert_eq!(a.logits.len(), 3, "one class-logit row");
        assert!(a.logits.iter().all(|v| v.is_finite()));
        assert_eq!(a.logits, b.logits, "the digital forward is deterministic");
        assert!(server.routing().is_empty(), "one backend: no router, hash placement");
        server.shutdown().expect("clean shutdown");
    }

    #[test]
    fn mixed_pool_routes_and_serves_through_backend_cost_models() {
        let registry = SharedRegistry::new();
        registry.deploy("tight", adapter(1.0));
        registry.deploy("relaxed", adapter(2.0));
        let refitter: Arc<dyn Refitter> = Arc::new(FnRefitter(
            |_: &str,
             current: &ParamStore,
             _: &ParamStore,
             budget: usize|
             -> anyhow::Result<Refit> {
                Ok(Refit {
                    params: current.clone(),
                    steps: budget,
                })
            },
        ));
        let refresh = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), refitter)
            .tolerance(0.5)
            .task_tolerance("tight", 1e-6);
        // a deliberately expensive PCM refit: keeping the tight task
        // inside tolerance on the drifting substrate dwarfs the
        // digital slowdown, so the cost model MUST move it — while
        // the relaxed task's once-in-an-epoch refresh keeps it on the
        // faster analog path
        let server = Server::builder("base")
            .manifest(cls_manifest())
            .workers(2)
            .backend(Arc::new(PcmPjrt::default().refit_ns(5.0e9)))
            .backend(Arc::new(DigitalRef::default()))
            .refresh(refresh)
            .build(ParamStore::default(), registry)
            .expect("a mixed pool builds without artifacts");
        assert_eq!(
            server.routing(),
            vec![("relaxed".to_string(), 0), ("tight".to_string(), 1)],
            "tight tolerance moves to the drift-free backend, relaxed stays on PCM"
        );
        let client = server.client();
        let tokens: Vec<i32> = (0..16).collect();
        let resp = client.submit("tight", &tokens).unwrap().wait().unwrap();
        assert_eq!(resp.worker, 1, "the digital backend owns worker span [1, 2)");
        assert_eq!(resp.logits.len(), 3);
        // worker 0 is a PCM+PJRT worker with no artifacts behind it:
        // its bring-up failure surfaces at shutdown — the digital span
        // served real traffic regardless, which is the point
        assert!(server.shutdown().is_err());
    }

    /// The DigitalRef numerics knobs: with a PCM model attached the
    /// digital reference reproduces the analog error envelope —
    /// programming-noise σ(g_rel), the read-quantization grid, the
    /// ν-clip deviation clamp — fully deterministically, and turning
    /// `noise_scale` to zero restores the bit-exact clean path.
    #[test]
    fn digital_numerics_knobs_match_the_pcm_reference_envelope() {
        let m = cls_manifest();
        let meta = ParamStore::default();
        let lora = adapter(1.0);
        let tokens: Vec<i32> = (0..64).collect(); // 4 rows of seq 16
        let hw = [0.0f32, 0.0, 127.0, 8.0, 8.0];
        let logits = |backend: DigitalRef| {
            let fwd = backend.forward(&m, "base/fwd_cls").expect("hermetic forward");
            fwd.cls_logits(&meta, &lora, &tokens, hw, 7).expect("digital emit")
        };
        let clean = logits(DigitalRef::default());
        assert_eq!(clean.len(), 4, "one class-logit row per seq-length request");
        assert!(clean.iter().all(|r| r.len() == 3));
        assert_eq!(clean, logits(DigitalRef::default()), "the clean path is deterministic");

        let model = PcmModel::default();
        let off = logits(DigitalRef::default().model(model.clone()).noise_scale(0.0));
        assert_eq!(off, clean, "noise_scale 0 must restore the bit-exact clean path");

        let noisy = logits(DigitalRef::default().model(model.clone()));
        assert_eq!(
            noisy,
            logits(DigitalRef::default().model(model.clone())),
            "the PCM error envelope is seeded, not stochastic"
        );
        let clip = model.nu_clip.1 + 1e-6;
        let mut perturbed = false;
        for (nr, cr) in noisy.iter().zip(&clean) {
            for (n, c) in nr.iter().zip(cr) {
                assert!(n.is_finite());
                assert!(
                    (n - c).abs() <= clip,
                    "deviation {n} vs {c} exceeds the ν-clip bound {clip}"
                );
                perturbed |= n != c;
            }
        }
        assert!(perturbed, "PCM numerics must actually perturb the logits");
    }

    /// Three-substrate adaptive pool end-to-end on the virtual clock:
    /// a fast-drifting PCM, a conservative PCM (slower service,
    /// cheaper maintenance), and the drift-free digital reference.
    /// Three tasks with order-of-magnitude different arrival rates
    /// start cold on the cheapest substrate; the cadenced rebalancer
    /// migrates each to its cost-optimal backend exactly once, and
    /// the drift physics follow the move — the fast-PCM resident
    /// keeps refreshing while the migrated tasks never swap again.
    #[test]
    fn adaptive_pool_separates_three_substrates_by_arrival_rate() {
        // the conservative refit is re-priced so that all three cost
        // crossovers land on the measured gap grid below (the stock
        // horizon puts the digital crossover past any plausible EWMA)
        let backends: Vec<Arc<dyn Backend>> = vec![
            Arc::new(PcmPjrt::default().refit_ns(5.0e9)),
            Arc::new(PcmPjrt::conservative().refit_ns(2.0e9)),
            Arc::new(DigitalRef::default()),
        ];
        let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
        let profiles: Vec<BackendProfile> = backends
            .iter()
            .map(|b| BackendProfile::of(b.as_ref(), &layer, refresh_sim::MAX_BATCH))
            .collect();
        // first measured gap that routes to `want` with every other
        // substrate at least 10% more expensive — a margin the traffic
        // simulation cannot erode; integer ns so the constant-gap EWMA
        // reproduces the scanned value exactly
        let gap_of = |want: usize| -> u64 {
            (0..280)
                .map(|k| 10f64.powf(2.0 + k as f64 * 0.05).ceil())
                .find(|&gap| {
                    let costs: Vec<f64> =
                        profiles.iter().map(|p| p.placement_cost(gap, 0.05)).collect();
                    route_one(&profiles, gap, 0.05) == want
                        && costs
                            .iter()
                            .enumerate()
                            .all(|(i, &c)| i == want || costs[want] * 1.1 < c)
                })
                .unwrap_or_else(|| panic!("no margined gap routes to backend {want}"))
                as u64
        };
        let cold = route_one(&profiles, f64::INFINITY, 0.05);
        assert_eq!(cold, 0, "at saturation the fast PCM is the cheapest substrate");
        let (g0, g1, g2) = (gap_of(0), gap_of(1), gap_of(2));
        assert!(g0 < g1 && g1 < g2, "crossover gaps must be ordered");

        let mut pool = SimPool::builder()
            .workers(3)
            .tasks(&["fast", "mid", "slow"])
            .backends(&backends)
            .rebalance(
                RebalanceConfig::new()
                    .hysteresis(0.05)
                    .cooldown(Duration::from_nanos(g2.saturating_mul(512)))
                    .idle_retire(None),
            )
            .trigger_in(Duration::from_nanos(4 * g2))
            .build();

        // merged arrival timeline: 40 arrivals per task at its own gap,
        // advanced event by event so rebalance sees every arrival
        let names = ["fast", "mid", "slow"];
        let gaps = [g0, g1, g2];
        let mut next = gaps;
        let mut left = [40usize; 3];
        let mut elapsed: u64 = 0;
        while let Some(i) = (0..3usize).filter(|&i| left[i] > 0).min_by_key(|&i| next[i]) {
            pool.advance(Duration::from_nanos(next[i] - elapsed));
            elapsed = next[i];
            pool.push(names[i]);
            left[i] -= 1;
            next[i] += gaps[i];
            pool.drain();
            pool.tick();
            pool.rebalance_tick();
        }
        pool.flush(Duration::from_millis(5));

        assert_eq!(pool.lat_ns.len(), 120, "every request served");
        let target: BTreeMap<&str, usize> =
            BTreeMap::from([("fast", 0), ("mid", 1), ("slow", 2)]);
        let router = pool.router.clone().expect("routed pool");
        for (task, &want) in &target {
            assert_eq!(
                router.backend_of(task),
                want,
                "task {task} must end on its cost-optimal substrate"
            );
        }
        // exactly one migration per task that did not start on its
        // optimum, none for the one that did
        assert_eq!(pool.moves.len(), 2, "mid and slow move, fast stays");
        for (_, mv) in &pool.moves {
            assert_eq!(mv.from, cold, "every migration leaves the cold placement");
            assert_eq!(mv.to, target[mv.task.as_str()]);
        }
        let moved: Vec<&str> = pool.moves.iter().map(|(_, mv)| mv.task.as_str()).collect();
        assert_eq!(moved, vec!["mid", "slow"], "moves land in arrival-evidence order");
        // drift physics follow the migration: the fast-PCM resident
        // keeps refreshing, the conservative horizon exceeds the run,
        // and the migrated-to-digital task stops triggering at all
        assert!(!pool.swaps_for("fast").is_empty(), "fast-PCM resident keeps refreshing");
        assert!(pool.handle.trigger_at("fast").is_some());
        assert!(
            pool.swaps_for("mid").is_empty(),
            "the conservative drift horizon exceeds the run"
        );
        assert!(pool.swaps_for("slow").is_empty(), "drift-free substrate never refreshes");
        assert_eq!(
            pool.handle.trigger_at("slow"),
            None,
            "migration rewired the slow task onto drift-free physics"
        );
    }

    /// Three-way Server routing through per-task tolerances: the
    /// relaxed task stays on the fast PCM, the tight task is priced
    /// off it by the maintenance bill, and a pinned task overrides
    /// the cost model onto the digital span — and serves real traffic
    /// there.
    #[test]
    fn three_backend_server_routes_tolerances_and_honors_pins() {
        let pcm = PcmPjrt::default().refit_ns(5.0e9);
        let cons = PcmPjrt::conservative().refit_ns(5.0e9);
        let dig = DigitalRef::default();
        // mirror the server's own placement inputs (graph seq 16,
        // builder max_batch 8): which substrate wins the tight task is
        // the calibrated latency model's call, so the test derives the
        // expectation from the same profiles the server routes on
        let layer = SchedConfig::for_layer(128, 128, 8).seq(16);
        let profiles = vec![
            BackendProfile::of(&pcm, &layer, 8),
            BackendProfile::of(&cons, &layer, 8),
            BackendProfile::of(&dig, &layer, 8),
        ];
        let expected_tight = route_one(&profiles, f64::INFINITY, 1e-6);
        assert_ne!(
            expected_tight, 0,
            "a tight tolerance must price the fast PCM out of the running"
        );
        assert_eq!(
            route_one(&profiles, f64::INFINITY, 0.5),
            0,
            "a relaxed tolerance keeps the fast PCM"
        );

        let registry = SharedRegistry::new();
        registry.deploy("tight", adapter(1.0));
        registry.deploy("relaxed", adapter(2.0));
        registry.deploy("pinned", adapter(3.0));
        let refitter: Arc<dyn Refitter> = Arc::new(FnRefitter(
            |_: &str,
             current: &ParamStore,
             _: &ParamStore,
             budget: usize|
             -> anyhow::Result<Refit> {
                Ok(Refit {
                    params: current.clone(),
                    steps: budget,
                })
            },
        ));
        let refresh = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), refitter)
            .tolerance(0.5)
            .task_tolerance("tight", 1e-6);
        let server = Server::builder("base")
            .manifest(cls_manifest())
            .workers(3)
            .backend(Arc::new(pcm))
            .backend(Arc::new(cons))
            .backend(Arc::new(dig))
            .pin_task("pinned", 2)
            .refresh(refresh)
            .build(ParamStore::default(), registry)
            .expect("a three-backend pool builds without artifacts");
        assert_eq!(
            server.routing(),
            vec![
                ("pinned".to_string(), 2),
                ("relaxed".to_string(), 0),
                ("tight".to_string(), expected_tight),
            ],
            "tolerances route through the cost model, pins override it"
        );
        let client = server.client();
        let tokens: Vec<i32> = (0..16).collect();
        let resp = client.submit("pinned", &tokens).unwrap().wait().unwrap();
        assert_eq!(resp.worker, 2, "the pinned task serves on the digital span [2, 3)");
        assert_eq!(resp.logits.len(), 3);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        // the two PCM+PJRT workers have no artifacts: their bring-up
        // failures surface at shutdown, after the digital span served
        assert!(server.shutdown().is_err());
    }
}
