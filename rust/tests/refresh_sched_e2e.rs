//! End-to-end conformance suite for refresh-aware batch scheduling.
//!
//! Everything here runs on ONE `VirtualClock` shared by the batcher,
//! the `BatchScheduler`, and the `RefreshRunner` — zero real sleeps, so
//! every assertion is exact: the same request stream (the shared
//! `SimPool` harness in `tests/common/refresh_sim.rs`, also driven by
//! `tests/coord_conformance.rs` and `benches/serving_refresh_sched.rs`)
//! is replayed with the scheduler coupled and uncoupled to the refresh
//! lifecycle, and the suite pins that
//!
//! * coupled: **zero** requests are served at the stale adapter version
//!   once the modeled `trigger_at` (plus the — here instant — refit
//!   budget) has passed, and **no batch spans the version bump**: the
//!   hot-swap lands between batches and the first post-swap batch
//!   serves the refreshed version immediately;
//! * uncoupled: the regression the coupling exists to fix is real —
//!   blind batching serves drift-degraded requests past the trigger and
//!   runs at least one batch across the swap.

#[path = "common/refresh_sim.rs"]
mod refresh_sim;

use refresh_sim::{simulate, N_REQUESTS_DEFAULT};

#[test]
fn coupled_scheduler_serves_zero_stale_requests_and_no_batch_spans_the_swap() {
    let run = simulate(true, N_REQUESTS_DEFAULT);
    assert_eq!(run.swap_version, 2, "exactly one refresh hot-swap fired");
    assert_eq!(run.served(), N_REQUESTS_DEFAULT, "every request served");

    // the headline guarantees
    assert_eq!(
        run.stale_after_trigger(),
        0,
        "coupling must eliminate post-trigger service at the stale version"
    );
    assert_eq!(
        run.spanning_batches(),
        0,
        "the hot-swap must land BETWEEN batches, never under one"
    );

    // the first post-swap batch serves the refreshed version at once
    let first_post = run.first_post_swap().expect("post-swap traffic exists");
    assert_eq!(first_post.version, 2, "first post-swap batch is fresh");

    // and the coupling visibly engaged (this is not a vacuous pass)
    assert!(run.drains > 0, "drift pressure shaped at least one close");
    assert!(run.holds > 0, "the overdue queue was held for the swap");
}

#[test]
fn uncoupled_baseline_exhibits_the_stale_batch_regression() {
    let run = simulate(false, N_REQUESTS_DEFAULT);
    assert_eq!(run.swap_version, 2, "the refresh itself is scheduler-agnostic");
    assert_eq!(run.served(), N_REQUESTS_DEFAULT, "every request still served");

    // the regression the coupling exists to fix, asserted as REAL:
    // blind batching serves drift-degraded requests past the trigger...
    assert!(
        run.stale_after_trigger() > 0,
        "uncoupled batching must exhibit stale post-trigger service"
    );
    // ...and runs at least one batch straight across the version bump
    assert!(
        run.spanning_batches() > 0,
        "uncoupled batching must run a batch across the hot-swap"
    );
    // no coupling: the pressure machinery must stay silent
    assert_eq!(run.drains, 0, "no Drain decisions without coupling");
    assert_eq!(run.holds, 0, "no Hold decisions without coupling");
}

#[test]
fn coupled_run_matches_uncoupled_throughput() {
    // coupling trades batch shape near the trigger, not delivery: both
    // modes serve the identical request stream to completion
    let coupled = simulate(true, N_REQUESTS_DEFAULT);
    let uncoupled = simulate(false, N_REQUESTS_DEFAULT);
    assert_eq!(coupled.served(), uncoupled.served());
    // and the stale-request delta is strictly in coupling's favour
    assert!(coupled.stale_after_trigger() < uncoupled.stale_after_trigger());
}
