//! Golden bit-identity tests for the staged compile pipeline
//! (`runtime::compile`).
//!
//! The pipeline's contract is that shape specialization is purely a
//! latency optimization: for every fill the scheduler can commit to —
//! and for every odd fill that falls back to the padded reference path
//! — the logits must match the unspecialized pipeline bit for bit, on
//! both the cls and qa heads. The host-side packing invariants are
//! property-tested hermetically; the PJRT goldens self-skip (with a
//! note on stderr) when the tiny artifacts have not been built.

use std::time::Duration;

use ahwa_lora::config::manifest::{default_artifacts_dir, GraphSpec, Manifest, Role};
use ahwa_lora::model::params::ParamStore;
use ahwa_lora::runtime::pack::PaddedChunks;
use ahwa_lora::runtime::{FwdPipeline, PrepackedBuf};
use ahwa_lora::serve::sched::{BatchScheduler, SchedConfig};
use ahwa_lora::util::proptest::check;
use ahwa_lora::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Host-side packing properties (hermetic)
// ---------------------------------------------------------------------------

#[test]
fn whole_multiple_inputs_never_emit_a_spurious_padded_chunk() {
    check("padded-chunks-whole-multiple", 64, |g| {
        let b = g.usize_in(1, 8);
        let s = g.usize_in(1, 12);
        let k = g.usize_in(1, 6); // full chunks
        let n = k * b;
        let tokens: Vec<i32> = (0..(n * s) as i32).collect();
        let mut chunks = PaddedChunks::new(&tokens, b, s);
        let mut seen = 0usize;
        while let Some((chunk, take, offset)) = chunks.next_chunk() {
            assert_eq!(take, b, "n % b == 0 must fill every chunk completely");
            assert_eq!(offset, seen * b, "chunk row offsets must be contiguous");
            assert_eq!(
                chunk,
                &tokens[seen * b * s..(seen + 1) * b * s],
                "a full chunk is a pure copy, no padding"
            );
            seen += 1;
        }
        assert_eq!(seen, k, "n % b == 0 must yield exactly n / b chunks");
    });
}

#[test]
fn partial_tail_chunk_is_zero_padded_and_counted_once() {
    check("padded-chunks-tail", 64, |g| {
        let b = g.usize_in(2, 8);
        let s = g.usize_in(1, 12);
        let n = g.usize_in(1, 3 * b);
        // 1-based payload so a zeroed pad row is distinguishable
        let tokens: Vec<i32> = (1..=(n * s) as i32).collect();
        let mut chunks = PaddedChunks::new(&tokens, b, s);
        let (mut rows, mut count) = (0usize, 0usize);
        while let Some((chunk, take, _)) = chunks.next_chunk() {
            assert!((1..=b).contains(&take));
            assert!(
                chunk[take * s..].iter().all(|&v| v == 0),
                "rows past the fill must be zero padding"
            );
            rows += take;
            count += 1;
        }
        assert_eq!(rows, n, "every input row must be yielded exactly once");
        assert_eq!(count, n.div_ceil(b));
    });
}

#[test]
fn prepacked_buffer_is_bit_identical_to_the_padded_reference() {
    check("prepacked-vs-padded", 64, |g| {
        let b = g.usize_in(1, 8);
        let s = g.usize_in(1, 12);
        let f = g.usize_in(1, b);
        let mut pre = PrepackedBuf::new(f, b, s);
        // two rounds with different payloads: the tail must stay zero
        // across packs, not just after construction
        for round in 0..2i32 {
            let tokens: Vec<i32> = (0..(f * s) as i32).map(|t| t + 1 + round * 1000).collect();
            let mut chunks = PaddedChunks::new(&tokens, b, s);
            let (reference, take, _) = chunks.next_chunk().unwrap();
            assert_eq!(take, f);
            assert_eq!(
                pre.pack(&tokens).unwrap(),
                reference,
                "prepacked buffer must produce the exact bytes of the padded path"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Digital-ref golden (hermetic, through the serve HAL's public surface)
// ---------------------------------------------------------------------------

#[cfg(feature = "digital-ref")]
mod digital_golden {
    use super::*;
    use std::collections::BTreeMap;

    use ahwa_lora::config::manifest::{HwDefaults, IoSpec};
    use ahwa_lora::model::params::Tensor;
    use ahwa_lora::serve::{Backend, DigitalRef, Forward};

    fn manifest() -> Manifest {
        let spec = GraphSpec {
            key: "base/fwd_cls".into(),
            kind: "fwd_cls".into(),
            variant: "base".into(),
            file: String::new(),
            inputs: vec![IoSpec {
                name: "data/tokens".into(),
                role: Role::Data,
                shape: vec![4, 16],
                dtype: "i32".into(),
            }],
            outputs: vec![IoSpec {
                name: "logits".into(),
                role: Role::Logits,
                shape: vec![4, 3],
                dtype: "f32".into(),
            }],
        };
        Manifest {
            root: std::path::PathBuf::from("unused"),
            hw: HwDefaults {
                weight_noise: 0.0,
                adc_noise: 0.0,
                clip_sigma: 127.0,
                dac_bits: 8,
                adc_bits: 8,
                g_max_us: 25.0,
                t0_seconds: 20.0,
            },
            grpo_group: 1,
            variants: BTreeMap::new(),
            graphs: BTreeMap::from([("base/fwd_cls".to_string(), spec)]),
        }
    }

    #[test]
    fn digital_backend_specialization_is_bit_identical_at_every_fill() {
        let be = DigitalRef::default();
        let m = manifest();
        let plain = be.forward(&m, "base/fwd_cls").unwrap();
        let mut spec = be.forward(&m, "base/fwd_cls").unwrap();

        // commit exactly what a scheduler on this substrate would
        let sched = BatchScheduler::new(
            be.adapt_sched(SchedConfig::for_layer(64, 64, 4).seq(16)),
            4,
            Duration::from_millis(5),
        );
        let fills = sched.committed_fills();
        assert!(!fills.is_empty());
        spec.specialize(&fills).unwrap();

        let meta = ParamStore::default();
        let mut t = Tensor::zeros("train/a", &[2, 2]);
        t.data[0] = 1.5;
        let adapter = ParamStore::from_tensors(vec![t]);
        let hw = [0.0, 0.0, 127.0, 127.0, 0.0];
        // every fill — committed or odd — must agree bit for bit
        for fill in 1..=4usize {
            let tokens: Vec<i32> = (0..(fill * 16) as i32).collect();
            let a = plain.cls_logits(&meta, &adapter, &tokens, hw, 7).unwrap();
            let b = spec.cls_logits(&meta, &adapter, &tokens, hw, 7).unwrap();
            assert_eq!(a.len(), fill);
            assert_eq!(a, b, "fill {fill}: specialization changed the logits");
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT goldens (need built artifacts; self-skip otherwise)
// ---------------------------------------------------------------------------

fn manifest_if_built() -> Option<Manifest> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).unwrap())
}

fn graph_key(manifest: &Manifest, kind: &str) -> Option<String> {
    manifest
        .graphs
        .values()
        .find(|g| g.kind == kind)
        .map(|g| g.key.clone())
}

/// Deterministic non-trivial parameters for a role, shaped by the spec.
fn randomized(spec: &GraphSpec, role: Role, seed: u64) -> ParamStore {
    let mut store = ParamStore::zeros_like_role(spec, role);
    let mut rng = Pcg64::new(seed);
    for t in &mut store.tensors {
        rng.fill_normal(&mut t.data, 0.0, 0.05);
    }
    store
}

/// Compile the same graph twice — once untouched (the padded reference
/// path) and once specialized on the scheduler's committed fills.
fn padded_and_specialized(manifest: &Manifest, key: &str) -> (FwdPipeline, FwdPipeline) {
    let padded = FwdPipeline::compile(manifest.clone(), key).unwrap();
    let mut specialized = FwdPipeline::compile(manifest.clone(), key).unwrap();
    let (batch, seq) = (padded.ir().batch, padded.ir().seq);
    let sched = BatchScheduler::new(
        SchedConfig::for_layer(128, 128, 8).seq(seq),
        batch,
        Duration::from_millis(5),
    );
    specialized.specialize(&sched.committed_fills()).unwrap();
    assert!(!specialized.specialized_fills().is_empty());
    (padded, specialized)
}

#[test]
fn specialized_cls_logits_match_the_padded_path_bit_for_bit() {
    let Some(manifest) = manifest_if_built() else { return };
    let Some(key) = graph_key(&manifest, "fwd_cls") else {
        eprintln!("skipping: no fwd_cls graph in the manifest");
        return;
    };
    let (padded, specialized) = padded_and_specialized(&manifest, &key);
    let spec = &padded.base().spec;
    let meta = randomized(spec, Role::Meta, 11);
    let train = randomized(spec, Role::Train, 13);
    let hw = [0.0f32, 3.0, 127.0, 127.0, 0.04];
    let (batch, seq) = (padded.ir().batch, padded.ir().seq);
    // every fill — the committed ones exercise the lowered paths, the
    // rest must fall back to the padded reference unchanged
    for fill in 1..=batch {
        let tokens: Vec<i32> = (0..(fill * seq) as i32).map(|t| t % 50).collect();
        let a = padded.cls_logits(&meta, &train, &tokens, hw, 42).unwrap();
        let b = specialized.cls_logits(&meta, &train, &tokens, hw, 42).unwrap();
        assert_eq!(a.len(), fill);
        assert_eq!(
            a, b,
            "fill {fill} (lowering {:?}): specialization changed the logits",
            specialized.lowering(fill)
        );
    }
}

#[test]
fn specialized_qa_predictions_match_the_padded_path() {
    let Some(manifest) = manifest_if_built() else { return };
    let Some(key) = graph_key(&manifest, "fwd_qa") else {
        eprintln!("skipping: no fwd_qa graph in the manifest");
        return;
    };
    let (padded, specialized) = padded_and_specialized(&manifest, &key);
    let spec = &padded.base().spec;
    let meta = randomized(spec, Role::Meta, 17);
    let train = randomized(spec, Role::Train, 19);
    let hw = [0.0f32, 3.0, 127.0, 127.0, 0.04];
    let (batch, seq) = (padded.ir().batch, padded.ir().seq);
    for fill in 1..=batch {
        let tokens: Vec<i32> = (0..(fill * seq) as i32).map(|t| t % 50).collect();
        let a = padded.qa_predict(&meta, &train, &tokens, hw, 42).unwrap();
        let b = specialized.qa_predict(&meta, &train, &tokens, hw, 42).unwrap();
        assert_eq!(a.len(), fill);
        assert_eq!(a, b, "fill {fill}: specialization changed the qa spans");
    }
}
