//! End-to-end integration over the real PJRT runtime and the tiny
//! artifacts: load HLO, train AHWA-LoRA, evaluate with the PCM device
//! model. This is the cross-layer contract test between python/aot.py
//! and the rust coordinator.

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest, Role};
use ahwa_lora::config::run::TrainConfig;
use ahwa_lora::data::squad::SquadTask;
use ahwa_lora::eval::drift_eval::{pcm_eval_hw, AnalogDeployment, QaEvalSet};
use ahwa_lora::model::checkpoint;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::runtime::Engine;
use ahwa_lora::train::{OwnedArg, OwnedBatch, Trainer};
use ahwa_lora::util::rng::Pcg64;

fn engine() -> Option<Engine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return None;
    }
    Some(Engine::new(Manifest::load(dir).unwrap()).unwrap())
}

fn load_inits(engine: &Engine, variant: &str, graph_tag: &str) -> (ahwa_lora::model::params::ParamStore, ahwa_lora::model::params::ParamStore) {
    let meta = checkpoint::load(engine.manifest.init_path(&format!("{variant}.meta"))).unwrap();
    let train = checkpoint::load(engine.manifest.init_path(&format!("{graph_tag}.train"))).unwrap();
    (meta, train)
}

#[test]
fn tiny_lora_training_reduces_loss() {
    let Some(engine) = engine() else { return };
    let (meta, train) = load_inits(&engine, "tiny", "tiny.step_qa_lora");
    let cfg = TrainConfig {
        steps: 30,
        lr: 5e-3,
        weight_noise: 0.05,
        log_every: 0,
        ..Default::default()
    };
    let variant = engine.manifest.variant("tiny").unwrap().clone();
    let task = SquadTask::new(variant.vocab, variant.seq);
    let mut trainer = Trainer::new(&engine, "tiny/step_qa_lora", meta, train, cfg).unwrap();
    let b = variant.train_batch;
    let losses = trainer
        .run(|_, rng| {
            let batch = task.batch(b, rng);
            OwnedBatch(vec![
                OwnedArg::I32(batch.tokens),
                OwnedArg::I32(batch.starts),
                OwnedArg::I32(batch.ends),
            ])
        })
        .unwrap();
    assert_eq!(losses.len(), 30);
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail = trainer.tail_loss(5);
    assert!(
        tail < head,
        "loss should decrease: head {head:.4} -> tail {tail:.4}"
    );
    assert!(!trainer.collapsed());
}

#[test]
fn full_ahwa_graph_trains_meta_tree() {
    let Some(engine) = engine() else { return };
    let g = engine.manifest.graph("tiny/step_qa_full").unwrap();
    // trainable tree strictly larger than lora graph's
    let lora_g = engine.manifest.graph("tiny/step_qa_lora").unwrap();
    assert!(g.param_count(Role::Train) > 5 * lora_g.param_count(Role::Train));
}

#[test]
fn fwd_and_pcm_drift_eval_compose() {
    let Some(engine) = engine() else { return };
    let (meta, train) = load_inits(&engine, "tiny", "tiny.step_qa_lora");
    let variant = engine.manifest.variant("tiny").unwrap().clone();
    let fwd = engine.load("tiny/fwd_qa").unwrap();

    let task = SquadTask::new(variant.vocab, variant.seq);
    let eval = QaEvalSet::generate(&task, 16, 99);

    // digital score (untrained net: near-random but valid)
    let hw = pcm_eval_hw(127.0, 127.0, 0.0);
    let (f1_digital, em) = eval.score(&fwd, &meta, &train, hw, 1).unwrap();
    assert!((0.0..=100.0).contains(&f1_digital) && (0.0..=100.0).contains(&em));

    // program onto PCM, read at 1 year, evaluate
    let mut rng = Pcg64::new(5);
    let dep = AnalogDeployment::program(meta, PcmModel::default(), 3.0, &mut rng);
    assert!(dep.n_devices() > 10_000);
    let meta_1y = dep.meta_at(31_536_000.0, true, &mut rng);
    let (f1_analog, _) = eval.score(&fwd, &meta_1y, &train, hw, 1).unwrap();
    assert!((0.0..=100.0).contains(&f1_analog));
}

#[test]
fn decoder_lm_graph_runs() {
    let Some(engine) = engine() else { return };
    let (meta, train) = load_inits(&engine, "tiny_dec", "tiny_dec.step_lm_lora");
    let fwd = engine.load("tiny_dec/fwd_lm").unwrap();
    let v = engine.manifest.variant("tiny_dec").unwrap().clone();
    let tokens = vec![4i32; v.eval_batch * v.seq];
    let logits = ahwa_lora::eval::drift_eval::lm_logits(
        &fwd,
        &meta,
        &train,
        &tokens,
        pcm_eval_hw(0.0, 0.0, 0.0),
        7,
    )
    .unwrap();
    assert_eq!(logits.len(), v.eval_batch * v.seq * v.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn training_is_deterministic_in_seed() {
    let Some(engine) = engine() else { return };
    let variant = engine.manifest.variant("tiny").unwrap().clone();
    let task = SquadTask::new(variant.vocab, variant.seq);
    let mut run = |seed: u64| -> Vec<f32> {
        let (meta, train) = load_inits(&engine, "tiny", "tiny.step_qa_lora");
        let cfg = TrainConfig {
            steps: 5,
            seed,
            log_every: 0,
            ..Default::default()
        };
        let mut t = Trainer::new(&engine, "tiny/step_qa_lora", meta, train, cfg).unwrap();
        t.run(|_, rng| {
            let b = task.batch(variant.train_batch, rng);
            OwnedBatch(vec![
                OwnedArg::I32(b.tokens),
                OwnedArg::I32(b.starts),
                OwnedArg::I32(b.ends),
            ])
        })
        .unwrap()
    };
    let a = run(11);
    let b = run(11);
    let c = run(12);
    assert_eq!(a, b, "same seed must reproduce exactly");
    assert_ne!(a, c, "different seed must differ");
}
