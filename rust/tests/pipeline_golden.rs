//! Golden regression pins for the Fig. 4 AIMC ⇄ PMCA pipeline model,
//! plus the scheduler ↔ balance-sweep consistency contract.
//!
//! Fully hermetic (pure cost model, no artifacts/PJRT). The pinned
//! numbers are the model's output at the seed of this test; any
//! scheduler or cycle-model refactor that silently drifts the Fig. 4c
//! series fails here instead of in a regenerated figure.

use std::time::Duration;

use ahwa_lora::pipeline::balance::{best, best_point, sweep};
use ahwa_lora::pipeline::schedule::{pipeline_latency, INTEGRATION_TIMES_NS, TOKEN_PARALLELISM};
use ahwa_lora::pmca::cluster::SnitchCluster;
use ahwa_lora::pmca::kernels::LoraWorkload;
use ahwa_lora::pmca::redmule::RedMulE;
use ahwa_lora::serve::{BatchScheduler, SchedConfig};

const SEQ: usize = 320; // the paper's sequence length
const RANK: usize = 8;

fn env() -> (SnitchCluster, RedMulE) {
    (SnitchCluster::default(), RedMulE::default())
}

/// The paper's full (layer, T_int, t) grid:
/// `(m, n, t_int_ns, t, pmca_ns, steady_ns)`.
#[rustfmt::skip]
const GOLDEN_GRID: [(usize, usize, f64, usize, f64, f64); 30] = [
    (128, 128, 128.0,   8,  1300.0,  52256.0),
    (128, 128, 128.0,  16,  2299.0,  46492.0),
    (128, 128, 128.0,  32,  4297.0,  43994.0),
    (128, 128, 128.0,  64,  8293.0,  43513.0),
    (128, 128, 128.0, 128, 16286.0,  53248.0),
    (128, 128, 256.0,   8,  1300.0,  82176.0),
    (128, 128, 256.0,  16,  2299.0,  82432.0),
    (128, 128, 256.0,  32,  4297.0,  82944.0),
    (128, 128, 256.0,  64,  8293.0,  83968.0),
    (128, 128, 256.0, 128, 16286.0, 102400.0),
    (128, 128, 512.0,   8,  1300.0, 164096.0),
    (128, 128, 512.0,  16,  2299.0, 164352.0),
    (128, 128, 512.0,  32,  4297.0, 164864.0),
    (128, 128, 512.0,  64,  8293.0, 165888.0),
    (128, 128, 512.0, 128, 16286.0, 200704.0),
    (512, 128, 128.0,   8,  2692.0, 107936.0),
    (512, 128, 128.0,  16,  5083.0, 102172.0),
    (512, 128, 128.0,  32,  9865.0,  99674.0),
    (512, 128, 128.0,  64, 19429.0,  99193.0),
    (512, 128, 128.0, 128, 38558.0, 119770.0),
    (512, 128, 256.0,   8,  2692.0, 107936.0),
    (512, 128, 256.0,  16,  5083.0, 102172.0),
    (512, 128, 256.0,  32,  9865.0,  99674.0),
    (512, 128, 256.0,  64, 19429.0,  99193.0),
    (512, 128, 256.0, 128, 38558.0, 119770.0),
    (512, 128, 512.0,   8,  2692.0, 164096.0),
    (512, 128, 512.0,  16,  5083.0, 164352.0),
    (512, 128, 512.0,  32,  9865.0, 164864.0),
    (512, 128, 512.0,  64, 19429.0, 165888.0),
    (512, 128, 512.0, 128, 38558.0, 200704.0),
];

/// Fig. 4c balance points: `(m, n, t_int_ns, best_t, overhead)`.
#[rustfmt::skip]
const GOLDEN_BEST: [(usize, usize, f64, usize, f64); 6] = [
    (128, 128, 128.0, 32, 0.0740722656),
    (128, 128, 256.0,  8, 0.0031250000),
    (128, 128, 512.0,  8, 0.0015625000),
    (512, 128, 128.0, 32, 1.4334472656),
    (512, 128, 256.0, 16, 0.2472167969),
    (512, 128, 512.0,  8, 0.0015625000),
];

#[test]
fn golden_grid_covers_the_papers_parameter_space() {
    // the pinned grid must stay in sync with the published constants
    let mut i = 0;
    for (m, n) in [(128usize, 128usize), (512, 128)] {
        for t_int in INTEGRATION_TIMES_NS {
            for t in TOKEN_PARALLELISM {
                let row = GOLDEN_GRID[i];
                assert_eq!((row.0, row.1, row.3), (m, n, t), "grid order at {i}");
                assert_eq!(row.2, t_int, "grid t_int at {i}");
                i += 1;
            }
        }
    }
    assert_eq!(i, GOLDEN_GRID.len());
}

#[test]
fn pipeline_latency_grid_is_pinned() {
    let (c, e) = env();
    for (m, n, t_int, t, pmca_ns, steady_ns) in GOLDEN_GRID {
        let w = LoraWorkload::new(m, n, RANK, t);
        let p = pipeline_latency(&w, t_int, SEQ, &c, &e);
        assert!(
            (p.pmca_ns - pmca_ns).abs() < 0.5,
            "{m}x{n}@{t_int} t={t}: pmca_ns {} != pinned {pmca_ns}",
            p.pmca_ns
        );
        assert!(
            (p.steady_ns - steady_ns).abs() < 0.5,
            "{m}x{n}@{t_int} t={t}: steady_ns {} != pinned {steady_ns}",
            p.steady_ns
        );
        // overhead is an identity of the pinned values — double-entry
        let expect_overhead = steady_ns / (SEQ as f64 * t_int) - 1.0;
        assert!(
            (p.overhead() - expect_overhead).abs() < 1e-9,
            "{m}x{n}@{t_int} t={t}: overhead {}",
            p.overhead()
        );
    }
}

#[test]
fn fig4c_balance_points_are_pinned() {
    let (c, e) = env();
    for (m, n, t_int, best_t, overhead) in GOLDEN_BEST {
        let b = best_point(m, n, RANK, t_int, SEQ, &c, &e);
        assert_eq!(b.t, best_t, "{m}x{n}@{t_int}: balance point moved");
        assert!(
            (b.overhead() - overhead).abs() < 1e-6,
            "{m}x{n}@{t_int}: overhead {} != pinned {overhead}",
            b.overhead()
        );
        assert!(b.fits_tcdm, "{m}x{n}@{t_int}: best point must fit the TCDM");
    }
}

/// Acceptance contract: the serving scheduler commits to exactly the
/// token parallelism `pipeline::balance::sweep` + `best` would pick, for
/// every Fig. 4 configuration, regardless of its own batching knobs.
#[test]
fn sched_matches_balance_sweep_for_every_fig4_config() {
    let (c, e) = env();
    for (m, n) in [(128usize, 128usize), (512, 128)] {
        for t_int in INTEGRATION_TIMES_NS {
            let reference = best(&sweep(m, n, RANK, t_int, SEQ, &c, &e));
            for max_batch in [1usize, 4, 8, 32] {
                let s = BatchScheduler::new(
                    SchedConfig::for_layer(m, n, RANK).t_int(t_int).seq(SEQ),
                    max_batch,
                    Duration::from_millis(5),
                );
                assert_eq!(
                    s.t_opt(),
                    reference.t,
                    "{m}x{n}@{t_int} max_batch={max_batch}: scheduler diverged from sweep"
                );
                assert!(
                    (s.balance_point().overhead() - reference.overhead()).abs() < 1e-12,
                    "{m}x{n}@{t_int}: overhead diverged"
                );
                // a single-request batch is exactly the Fig. 4 pipeline
                // run over one sequence at the committed parallelism
                let w = LoraWorkload::new(m, n, RANK, reference.t);
                let one = pipeline_latency(&w, t_int, SEQ, &c, &e).steady_ns;
                assert!((s.modeled_batch_ns(1) - one).abs() < 1e-9);
            }
        }
    }
}
